"""Curriculum trainer + sampler: corpus runs, recompile bounds, resume.

The CI ``corpus`` smoke job runs this module on every PR so the
sampler/bucketing path is exercised continuously, not just tier-1.
"""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import restore_policy, save_policy
from repro.core import (CompGraph, HSDAGConfig, extract_features,
                        paper_platform, shared_feature_config, simulate)
from repro.core.train import CurriculumSampler, CurriculumTrainer
from repro.graphs import build_corpus, corpus_fingerprint

from conftest import random_dag

PLAT = paper_platform()


def _cfg(**kw):
    base = dict(num_devices=2, hidden_channel=32, max_episodes=4,
                update_timestep=3, batch_chains=2)
    base.update(kw)
    return HSDAGConfig(**base)


def _small_corpus(count=8, size=18, seed=0):
    return build_corpus(f"synthetic:family=mixed:count={count}:size={size}"
                        f":seed={seed}")


# ----------------------------------------------------------------- sampler
def test_sampler_stratified_cycles_buckets():
    s = CurriculumSampler([[0, 1], [2], [3, 4]], graphs_per_episode=2,
                          strategy="stratified", seed=0)
    assert [s.sample()[0] for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_sampler_membership_and_replacement():
    s = CurriculumSampler([[0, 1, 2, 3], [4]], graphs_per_episode=3,
                          strategy="uniform", seed=1)
    for _ in range(20):
        bi, ids = s.sample()
        assert set(ids) <= set(s.buckets[bi])
        assert len(ids) == 3
        if bi == 0:
            assert len(set(ids)) == 3      # big enough → no replacement


def test_sampler_plateau_boosts_stale_graphs():
    s = CurriculumSampler([[0, 1]], graphs_per_episode=1,
                          strategy="plateau", seed=2, plateau_patience=2,
                          plateau_boost=50.0)
    # graph 0 keeps improving, graph 1 is stuck
    best = np.asarray([1.0, 1.0])
    for ep in range(6):
        s.observe([0, 1], best)
        best = best * np.asarray([0.9, 1.0])
    draws = [s.sample()[1][0] for _ in range(60)]
    assert draws.count(1) > draws.count(0)     # stale graph dominates


def test_sampler_state_roundtrip_continues_identically():
    def fresh():
        return CurriculumSampler([[0, 1, 2], [3, 4]], graphs_per_episode=2,
                                 strategy="uniform", seed=5)

    a = fresh()
    for _ in range(4):
        a.sample()
    state = a.state_dict()
    import json
    state = json.loads(json.dumps(state))     # must survive JSON transport
    b = fresh()
    b.load_state_dict(state)
    assert [a.sample() for _ in range(6)] == [b.sample() for _ in range(6)]


def test_sampler_validation():
    with pytest.raises(ValueError, match="strategy"):
        CurriculumSampler([[0]], strategy="bogus")
    with pytest.raises(ValueError):
        CurriculumSampler([[0]], graphs_per_episode=0)
    with pytest.raises(ValueError):
        CurriculumSampler([[0], []])
    s = CurriculumSampler([[0], [1]], seed=0)
    other = CurriculumSampler([[0, 1]], seed=0)
    with pytest.raises(ValueError, match="bucket partition"):
        s.load_state_dict(other.state_dict())


# ---------------------------------------------------------- corpus training
@pytest.mark.slow
def test_curriculum_mixed_corpus_smoke():
    """Acceptance-shaped (scaled down for CI): a ≥12-graph mixed corpus —
    benchmark + traced LM layer + synthetic — trains with jit recompiles
    bounded by #buckets, and every graph greedy-decodes to a placement that
    replays exactly on the host simulator."""
    corpus = build_corpus(
        "benchmark:names=resnet50;traced:archs=qwen1.5-0.5b:seq_len=16;"
        "synthetic:family=mixed:count=10:size=20:seed=4")
    assert len(corpus) >= 12
    tr = CurriculumTrainer(_cfg(max_episodes=5), max_buckets=3,
                           graphs_per_episode=3)
    res = tr.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(0))
    assert 1 <= len(res.buckets) <= 3
    assert res.episodes_run == 5
    # recompile bound: one shape per bucket for the train ops, plus at most
    # one decode shape per bucket (greedy ops carry no sim tree)
    assert len(tr.engine.shape_keys_seen) <= 2 * len(res.buckets)
    # every graph (sampled or not) got a greedy decode that replays exactly
    assert np.isfinite(res.greedy_latencies).all()
    for g, p, lat in zip(corpus, res.greedy_placements,
                         res.greedy_latencies):
        assert p.shape == (g.num_nodes,)
        np.testing.assert_allclose(simulate(g, p, PLAT).latency, lat,
                                   rtol=1e-5)
    # sampled graphs' bests replay too
    for i, (g, lat) in enumerate(zip(corpus, res.best_latencies)):
        if np.isfinite(lat):
            np.testing.assert_allclose(
                simulate(g, res.best_placements[i], PLAT).latency, lat,
                rtol=1e-5)


@pytest.mark.slow
def test_curriculum_resume_bitwise(tmp_path):
    """3 episodes + checkpoint + 3 resumed episodes ≡ 6 straight episodes:
    same final params (bitwise) and same cumulative bests."""
    corpus = _small_corpus(6, 14, seed=9)
    cfg = _cfg(max_episodes=6)
    kw = dict(max_buckets=2, graphs_per_episode=2)

    tr1 = CurriculumTrainer(cfg, **kw)
    r1 = tr1.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(7))

    d = str(tmp_path / "ckpt")
    tr2 = CurriculumTrainer(cfg, **kw)
    tr2.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(7),
                     episodes=3, checkpoint_dir=d, checkpoint_every=1)
    tr3 = CurriculumTrainer(cfg, **kw)
    r3 = tr3.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(7),
                          checkpoint_dir=d, resume=True)
    assert r3.episodes_run == 3
    assert [h["episode"] for h in r3.history] == [3, 4, 5]
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(r1.best_latencies, r3.best_latencies)


@pytest.mark.slow
def test_curriculum_resume_bitwise_with_ema_baseline(tmp_path):
    """The EMA baseline feeds step_weights, so its state must ride in the
    checkpoint too (regression: a resumed use_baseline run used to restart
    the EMA from scratch and silently diverge)."""
    corpus = _small_corpus(4, 12, seed=11)
    cfg = _cfg(max_episodes=4, use_baseline=True, normalize_weights=True)
    kw = dict(max_buckets=2, graphs_per_episode=2, reward_norm="none")

    tr1 = CurriculumTrainer(cfg, **kw)
    r1 = tr1.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(3))

    d = str(tmp_path / "ckpt")
    tr2 = CurriculumTrainer(cfg, **kw)
    tr2.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(3),
                     episodes=2, checkpoint_dir=d, checkpoint_every=1)
    tr3 = CurriculumTrainer(cfg, **kw)
    r3 = tr3.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(3),
                          checkpoint_dir=d, resume=True)
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_curriculum_resume_rejects_other_corpus(tmp_path):
    corpus = _small_corpus(4, 12, seed=1)
    d = str(tmp_path / "ckpt")
    tr = CurriculumTrainer(_cfg(), max_buckets=2, graphs_per_episode=2)
    tr.train_corpus(corpus, platform=PLAT, episodes=1, checkpoint_dir=d,
                    checkpoint_every=1)
    other = _small_corpus(4, 12, seed=2)
    tr2 = CurriculumTrainer(_cfg(), max_buckets=2, graphs_per_episode=2)
    with pytest.raises(ValueError, match="fingerprint"):
        tr2.train_corpus(other, platform=PLAT, checkpoint_dir=d,
                         resume=True)
    assert corpus_fingerprint(corpus) != corpus_fingerprint(other)


# ------------------------------------------------------------- warm start
def _trained_policy_dir(tmp_path, corpus):
    tr = CurriculumTrainer(_cfg(max_episodes=2), max_buckets=2,
                           graphs_per_episode=2)
    tr.train_corpus(corpus, platform=PLAT, rng=jax.random.PRNGKey(0))
    d = str(tmp_path / "policy")
    tr.save_policy(d)
    return d, tr


@pytest.mark.slow
def test_warm_start_restores_and_fine_tunes(tmp_path):
    corpus = _small_corpus(5, 16, seed=3)
    d, tr = _trained_policy_dir(tmp_path, corpus)
    held = _small_corpus(1, 16, seed=77)
    ft = CurriculumTrainer(_cfg(max_episodes=2), max_buckets=1,
                           graphs_per_episode=1)
    ft.warm_start(d)
    res = ft.train_corpus(held, platform=PLAT, rng=jax.random.PRNGKey(1))
    assert np.isfinite(res.best_latencies).all()
    # the restored feature layout (not a fresh one) was used
    assert ft.feature_config == tr.feature_config


def test_warm_start_vocab_mismatch_names_op_types(tmp_path):
    corpus = _small_corpus(4, 14, seed=5)
    d, _ = _trained_policy_dir(tmp_path, corpus)
    g = CompGraph("exotic")
    g.add_op("a", "FancyFused", [], (1, 8), flops=100, bytes_out=32)
    g.add_op("b", "MatMul", ["a"], (1, 8), flops=100, bytes_out=32)
    ft = CurriculumTrainer(_cfg(), max_buckets=1, graphs_per_episode=1)
    ft.warm_start(d)
    with pytest.raises(ValueError) as exc:
        ft.train_corpus([g], platform=PLAT, episodes=1)
    assert "FancyFused" in str(exc.value)
    assert "exotic" in str(exc.value)


def test_restore_policy_validates_graphs(tmp_path):
    """The checkpoint-layer hook: restore_policy(graphs=...) rejects graphs
    outside the saved vocabulary by name."""
    rng = np.random.default_rng(0)
    graphs = [random_dag(rng, 8, p=0.3), random_dag(rng, 12, p=0.2)]
    fc = shared_feature_config(graphs)
    arrays = extract_features(graphs[0], fc)
    from repro.core import MultiGraphTrainer
    tr = MultiGraphTrainer(_cfg(max_episodes=1))
    tr.train_multi(graphs, platform=PLAT, rng=jax.random.PRNGKey(0),
                   feature_cfg=fc,
                   arrays=[extract_features(g, fc) for g in graphs])
    d = str(tmp_path / "p")
    tr.save_policy(d)
    params, fc2, _, _ = restore_policy(d, tr.params, graphs=graphs)
    assert fc2 == fc
    weird = CompGraph("w")
    weird.add_op("n", "NotInVocab", [], (1, 2), flops=1, bytes_out=8)
    with pytest.raises(ValueError, match="NotInVocab"):
        restore_policy(d, tr.params, graphs=[weird])


def test_warm_start_requires_feature_config(tmp_path):
    d = str(tmp_path / "bare")
    save_policy(d, {"w": np.zeros(3, np.float32)})      # no feature layout
    ft = CurriculumTrainer(_cfg())
    with pytest.raises(ValueError, match="feature_config"):
        ft.warm_start(d)


@pytest.mark.slow
def test_streaming_corpus_trains_bitwise_equal():
    """A StreamingCorpus run replays the eager run bit for bit: metadata
    bucket shapes equal the sim_arrays-derived ones, the LRU only changes
    residency."""
    spec = "synthetic:family=mixed:count=8:size=18:seed=0"

    def run(graphs):
        t = CurriculumTrainer(_cfg(), max_buckets=2, graphs_per_episode=2,
                              stream_cache=3)
        return t.train_corpus(graphs, platform=PLAT)

    ref = run(build_corpus(spec))
    got = run(build_corpus("stream:" + spec))
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(got.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(ref.best_latencies, got.best_latencies)
    np.testing.assert_array_equal(ref.greedy_latencies,
                                  got.greedy_latencies)
    assert [h["graphs"] for h in ref.history] == \
        [h["graphs"] for h in got.history]
