"""Unit + property tests for the CompGraph IR (paper §2.1–2.2, Appendix G)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: property tests skip cleanly
    from conftest import given, settings, st

from repro.core import CompGraph, topological_order, colocate_chains
from repro.core.graph import OpNode

from conftest import make_diamond, random_dag


def test_adjacency_shape_and_asymmetry(diamond):
    a = diamond.adjacency()
    assert a.shape == (7, 7)
    assert a.sum() == diamond.num_edges
    assert np.all(np.diag(a) == 0)


def test_degrees(diamond):
    assert diamond.in_degrees()[diamond.index_of("cat")] == 2
    assert diamond.out_degrees()[diamond.index_of("in")] == 2


def test_topological_order_valid(diamond):
    order = topological_order(diamond)
    pos = np.empty(diamond.num_nodes, dtype=int)
    pos[order] = np.arange(diamond.num_nodes)
    for s, d in diamond.edges:
        assert pos[s] < pos[d]


def test_cycle_detection():
    g = CompGraph("cyclic")
    g.add_op("a", "X")
    g.add_op("b", "X", ["a"])
    g.add_edge("b", "a")
    with pytest.raises(ValueError):
        topological_order(g)


def test_duplicate_name_rejected():
    g = CompGraph("dup")
    g.add_op("a", "X")
    with pytest.raises(ValueError):
        g.add_op("a", "Y")


def test_colocate_chains_merges_linear_runs():
    g = CompGraph("chain")
    for i in range(5):
        g.add_op(f"n{i}", "Op", [f"n{i-1}"] if i else [], flops=1.0)
    coarse, labels = colocate_chains(g)
    assert coarse.num_nodes == 1           # pure chain collapses fully
    assert len(set(labels.tolist())) == 1
    assert coarse.nodes[0].flops == 5.0    # flops aggregate


def test_colocate_preserves_branches(diamond):
    coarse, labels = colocate_chains(diamond)
    # 'in' has two children: must not merge with either branch head.
    assert labels[diamond.index_of("in")] not in (
        labels[diamond.index_of("a")], labels[diamond.index_of("b")])
    coarse.validate_acyclic()


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000))
def test_topo_order_property_random_dags(n, seed):
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    order = topological_order(g)
    pos = np.empty(n, dtype=int)
    pos[order] = np.arange(n)
    for s, d in g.edges:
        assert pos[s] < pos[d]


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000))
def test_colocation_property_random_dags(n, seed):
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    coarse, labels = colocate_chains(g)
    # Contraction conserves totals and stays acyclic.
    assert coarse.num_nodes == len(set(labels.tolist()))
    assert np.isclose(coarse.flops().sum(), g.flops().sum())
    coarse.validate_acyclic()


def test_subgraph_contraction_majority_type():
    g = CompGraph("m")
    g.add_op("a", "MatMul")
    g.add_op("b", "MatMul", ["a"])
    g.add_op("c", "ReLU", ["b"])
    cg = g.subgraph_contraction(np.array([0, 0, 0]))
    assert cg.num_nodes == 1
    assert cg.nodes[0].op_type == "MatMul"
