"""RewardPipeline contracts — padded-row trimming for host reward_fns.

Regression suite for the PR-7 serving bugfix: ``_score_single`` handed the
full padded (V_max,) placement row to ``reward_fn`` while ``_score_multi``
trimmed to the graph's true ``:num_nodes`` prefix.  A bucket-padded
single-graph rollout therefore fed pad slots to the ``MeasuredExecutor``
slot.  These tests fail on the pre-fix code.
"""
import numpy as np
import pytest

from repro.core import paper_platform, simulate
from repro.core.sim.pipeline import RewardPipeline

from conftest import make_diamond

PLAT = paper_platform()


def _padded_fines(T, B, nn, v_max, rng):
    """(T, B, V_max) placements whose pad slots carry garbage device ids."""
    fines = rng.integers(0, 2, size=(T, B, v_max))
    fines[:, :, nn:] = 97  # poison: any consumer of pad slots must notice
    return fines


def test_score_single_reward_fn_trims_pad_slots():
    g = make_diamond()
    nn, v_max = g.num_nodes, g.num_nodes + 9
    seen_lengths = []

    def reward_fn(p):
        seen_lengths.append(len(p))
        assert not np.any(np.asarray(p) == 97), \
            "reward_fn received pad slots from a padded rollout row"
        r = simulate(g, np.asarray(p), PLAT)
        return r.reward, r.latency

    pipe = RewardPipeline.from_reward_fn(reward_fn, num_nodes=nn)
    fines = _padded_fines(3, 2, nn, v_max, np.random.default_rng(0))
    rewards, latencies = pipe.score_window(fines)
    assert rewards.shape == latencies.shape == (3, 2)
    assert seen_lengths == [nn] * (3 * 2)


def test_score_single_matches_unpadded_scores():
    """Padded and unpadded windows of the same placements score equal."""
    g = make_diamond()
    nn = g.num_nodes

    def reward_fn(p):
        r = simulate(g, np.asarray(p), PLAT)
        return r.reward, r.latency

    rng = np.random.default_rng(1)
    base = rng.integers(0, 2, size=(2, 3, nn))
    padded = np.full((2, 3, nn + 5), 97, dtype=base.dtype)
    padded[:, :, :nn] = base

    exact = RewardPipeline.from_reward_fn(reward_fn,
                                          num_nodes=nn).score_window(base)
    trimmed = RewardPipeline.from_reward_fn(reward_fn,
                                            num_nodes=nn).score_window(padded)
    np.testing.assert_array_equal(exact[0], trimmed[0])
    np.testing.assert_array_equal(exact[1], trimmed[1])


def test_score_single_backend_trims_pad_slots():
    """The simulator-backend path trims too (prep is built unpadded)."""
    g = make_diamond()
    nn = g.num_nodes
    pipe = RewardPipeline.from_platform(g, PLAT, backend="reference")
    rng = np.random.default_rng(2)
    base = rng.integers(0, 2, size=(2, 2, nn))
    padded = np.full((2, 2, nn + 7), 97, dtype=base.dtype)
    padded[:, :, :nn] = base
    r_pad, l_pad = pipe.score_window(padded)
    r_ref, l_ref = pipe.score_window(base)
    np.testing.assert_allclose(r_pad, r_ref)
    np.testing.assert_allclose(l_pad, l_ref)


def test_from_reward_fn_without_num_nodes_passes_rows_through():
    """Legacy callers (no padding) keep the identity contract."""
    rows = []

    def reward_fn(p):
        rows.append(np.asarray(p).copy())
        return 0.0, 0.0

    pipe = RewardPipeline.from_reward_fn(reward_fn)
    fines = np.arange(2 * 1 * 4).reshape(2, 1, 4)
    pipe.score_window(fines)
    np.testing.assert_array_equal(rows[0], fines[0, 0])
    assert all(r.shape == (4,) for r in rows)


def test_score_window_rejects_bad_rank():
    pipe = RewardPipeline.from_reward_fn(lambda p: (0.0, 0.0))
    with pytest.raises(ValueError, match="placements"):
        pipe.score_window(np.zeros((3, 4)))
