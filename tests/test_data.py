"""Data pipeline: determinism, resumability, label alignment, structure."""
import numpy as np

from repro.data import DataConfig, SyntheticTokens


def test_batches_deterministic_per_step():
    cfg = DataConfig(vocab_size=101, seq_len=32, global_batch=4, seed=7)
    a = SyntheticTokens(cfg).batch(5)
    b = SyntheticTokens(cfg).batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    np.testing.assert_array_equal(np.asarray(a["labels"]),
                                  np.asarray(b["labels"]))


def test_different_steps_differ():
    cfg = DataConfig(vocab_size=101, seq_len=32, global_batch=4)
    src = SyntheticTokens(cfg)
    a, b = src.batch(0), src.batch(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=2)
    batch = SyntheticTokens(cfg).batch(0)
    t = np.asarray(batch["tokens"])
    l = np.asarray(batch["labels"])
    # labels[t] == tokens[t+1] within the underlying sequence
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


def test_tokens_in_vocab_and_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=2, repeat_p=0.4)
    batch = SyntheticTokens(cfg).batch(0)
    t = np.asarray(batch["tokens"])
    assert t.min() >= 0 and t.max() < 64
    # repetition structure: adjacent-window repeats far above chance
    hits = np.mean([
        t[b, i] in t[b, max(0, i - 8):i]
        for b in range(2) for i in range(1, 256)])
    assert hits > 0.3
