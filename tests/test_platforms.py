"""Tests for the topology-aware platform subsystem (``repro.platforms``).

Covers the tiered-interconnect builders, the ``(D, F_DEV)`` device feature
table, the exact series-parallel DP (brute-force cross-checked — the
"provably optimal" acceptance gate), the hybrid refiner, the capacity-aware
action mask of the ``head="device"`` policy, and the CLI platform-spec
parser's error contract.
"""
import itertools

import numpy as np
import pytest

from repro.core import (CompGraph, FeatureConfig, HSDAG, HSDAGConfig,
                        extract_features, simulate)
from repro.core.baselines import dp_placement, hybrid_placement
from repro.core.costmodel import sim_arrays
from repro.core.policy import policy_apply, policy_init
from repro.graphs.synthetic import series_parallel_dag
from repro.platforms import (DEV_FEATURE_DIM, LinkTier, Topology,
                             device_feature_table, dp_optimal, hybrid_refine,
                             multi_host, nvlink_island, ring, sp_decompose,
                             torus)

jax = pytest.importorskip("jax")

# Ample queues keep list scheduling contention-free — the regime where the
# SP DP objective *is* the makespan (see repro/platforms/exact.py).
_Q = 16


# --------------------------------------------------------------- builders

def test_nvlink_island_link_structure():
    plat = nvlink_island(islands=2, gpus_per_island=2)
    assert plat.num_devices == 4
    bw = np.asarray(plat.link_bw)
    assert np.all(np.isinf(np.diagonal(bw)))
    assert bw[0, 1] == pytest.approx(300e9)      # intra-island NVLink
    assert bw[0, 2] == pytest.approx(25e9)       # cross-island PCIe
    assert plat.coords.shape == (4, 2)
    # Non-uniform by construction: more than one distinct off-diagonal bw.
    off = bw[~np.eye(4, dtype=bool)]
    assert len(np.unique(off)) == 2


def test_nvlink_island_heterogeneous_scaling():
    plat = nvlink_island(islands=2, gpus_per_island=2, island_scale=0.5)
    flops = [d.peak_flops for d in plat.devices]
    assert flops[0] == pytest.approx(2 * flops[2])


def test_multi_host_three_tiers():
    plat = multi_host(hosts=2, gpus_per_host=2)
    bw = np.asarray(plat.link_bw)
    assert bw[0, 1] == pytest.approx(300e9)      # NVLink bridge pair
    assert bw[0, 2] == pytest.approx(12.5e9)     # cross-host NIC
    lat = np.asarray(plat.link_latency)
    assert lat[0, 2] == pytest.approx(20e-6)


def test_torus_and_ring_hop_degradation():
    plat = torus(rows=2, cols=2)
    bw = np.asarray(plat.link_bw)
    assert bw[0, 1] == pytest.approx(50e9)       # 1 hop
    assert bw[0, 3] == pytest.approx(25e9)       # 2 hops: bw / 2
    assert np.asarray(plat.link_latency)[0, 3] == pytest.approx(4e-6)
    rplat = ring(devices=5)
    rbw = np.asarray(rplat.link_bw)
    assert rbw[0, 1] == pytest.approx(50e9)
    assert rbw[0, 2] == pytest.approx(25e9)      # wraparound distance 2
    assert rbw[0, 4] == pytest.approx(50e9)      # wraparound neighbor


def test_builder_argument_validation():
    with pytest.raises(ValueError, match="islands"):
        nvlink_island(islands=0)
    with pytest.raises(ValueError, match="island_scale"):
        nvlink_island(island_scale=1.5)
    with pytest.raises(ValueError, match="devices"):
        ring(devices=0)


def test_topology_tier_index_validation_names_entry():
    dev = nvlink_island(islands=1, gpus_per_island=2).devices
    with pytest.raises(ValueError, match=r"tier_index\[0, 1\]"):
        Topology(devices=dev, tiers=(LinkTier("x", 1e9, 0.0),),
                 tier_index=np.array([[0, 7], [0, 0]]),
                 coords=np.zeros((2, 1)))


def test_link_tier_validation():
    with pytest.raises(ValueError, match="bandwidth"):
        LinkTier("bad", 0.0, 1e-6)
    with pytest.raises(ValueError, match="latency"):
        LinkTier("bad", 1e9, -1.0)


# --------------------------------------------------- device feature table

@pytest.mark.parametrize("build", [
    lambda: nvlink_island(islands=2, gpus_per_island=2),
    lambda: multi_host(hosts=2, gpus_per_host=2),
    lambda: torus(rows=2, cols=4),
    lambda: ring(devices=3),
])
def test_device_feature_table_shape_and_range(build):
    plat = build()
    tab = device_feature_table(plat)
    assert tab.shape == (plat.num_devices, DEV_FEATURE_DIM)
    assert tab.dtype == np.float32
    assert np.all(np.isfinite(tab))
    assert np.all(tab >= 0.0) and np.all(tab <= 1.0)


def test_device_feature_table_separates_heterogeneous_islands():
    plat = nvlink_island(islands=2, gpus_per_island=2, island_scale=0.5)
    tab = device_feature_table(plat)
    # Island 0 is the fleet max; island 1 runs at half rate.
    assert np.allclose(tab[:2, 0], 1.0)
    assert np.allclose(tab[2:, 0], 0.5)
    # Coordinate columns distinguish islands.
    assert not np.allclose(tab[0, 9:], tab[2, 9:])


# ------------------------------------------------------------ exact SP DP

def _brute_force(g: CompGraph, platform):
    best_lat, best_p = np.inf, None
    for p in itertools.product(range(platform.num_devices),
                               repeat=g.num_nodes):
        res = simulate(g, np.asarray(p), platform)
        if not res.oom and res.latency < best_lat:
            best_lat, best_p = res.latency, np.asarray(p)
    return best_p, best_lat


def test_dp_optimal_matches_brute_force_two_devices():
    g = series_parallel_dag(target_nodes=10, seed=0)       # 11 nodes
    plat = ring(devices=2, parallel_queues=_Q)
    res = dp_optimal(g, plat)
    assert res is not None and not res.oom
    _, brute_lat = _brute_force(g, plat)
    assert res.latency == pytest.approx(brute_lat, rel=1e-9)
    assert res.bound == pytest.approx(res.latency, rel=1e-6)
    assert simulate(g, res.placement, plat).latency == \
        pytest.approx(res.latency, rel=1e-9)


@pytest.mark.slow
def test_dp_optimal_matches_brute_force_heterogeneous_four_devices():
    g = series_parallel_dag(target_nodes=6, seed=7)        # 7 nodes
    plat = nvlink_island(islands=2, gpus_per_island=2, island_scale=0.5,
                         parallel_queues=_Q)
    res = dp_optimal(g, plat)
    assert res is not None and not res.oom
    _, brute_lat = _brute_force(g, plat)
    assert res.latency == pytest.approx(brute_lat, rel=1e-9)


def test_dp_single_node_graph():
    g = CompGraph("one")
    g.add_op("x", "MatMul", output_shape=(1, 8), flops=1e6, bytes_out=32)
    res = dp_optimal(g, ring(devices=3, parallel_queues=_Q))
    assert res is not None
    assert res.placement.shape == (1,)
    assert res.latency == pytest.approx(
        simulate(g, res.placement, ring(devices=3, parallel_queues=_Q))
        .latency)


def _non_sp_graph() -> CompGraph:
    """The forbidden "N" minor: diamond with a cross edge a→b."""
    g = CompGraph("n-graph")
    g.add_op("s", "Parameter", output_shape=(1, 8), flops=0, bytes_out=32)
    g.add_op("a", "MatMul", ["s"], (1, 8), flops=1e6, bytes_out=32)
    g.add_op("b", "MatMul", ["s", "a"], (1, 8), flops=1e6, bytes_out=32)
    g.add_op("t", "Add", ["a", "b"], (1, 8), flops=8, bytes_out=32)
    return g


def test_sp_decompose_rejects_non_sp():
    assert sp_decompose(_non_sp_graph()) is None
    assert dp_optimal(_non_sp_graph(),
                      ring(devices=2, parallel_queues=_Q)) is None


def test_dp_placement_baseline_raises_on_non_sp():
    with pytest.raises(ValueError, match="series-parallel"):
        dp_placement(_non_sp_graph(), ring(devices=2, parallel_queues=_Q))


def test_dp_placement_baseline_is_optimal():
    g = series_parallel_dag(target_nodes=10, seed=3)
    plat = multi_host(hosts=2, gpus_per_host=1, parallel_queues=_Q)
    p, lat = dp_placement(g, plat)
    assert p.shape == (g.num_nodes,)
    assert lat == pytest.approx(simulate(g, p, plat).latency, rel=1e-9)


# ---------------------------------------------------------- hybrid refine

def test_hybrid_refine_never_worse():
    g = series_parallel_dag(target_nodes=14, seed=1)
    plat = multi_host(hosts=2, gpus_per_host=2, parallel_queues=_Q)
    rng = np.random.default_rng(0)
    for _ in range(5):
        base = rng.integers(0, plat.num_devices, g.num_nodes)
        base_lat = simulate(g, base, plat).latency
        p, lat = hybrid_placement(g, base, plat)
        assert lat <= base_lat + 1e-12
        assert simulate(g, p, plat).latency == pytest.approx(lat, rel=1e-9)


def test_hybrid_reaches_optimum_on_pure_chain():
    g = CompGraph("chain")
    prev = None
    for i in range(8):
        g.add_op(f"n{i}", "MatMul", [prev] if prev else [], (1, 32),
                 flops=float(1e6 * (i + 1)), bytes_out=128.0)
        prev = f"n{i}"
    plat = nvlink_island(islands=2, gpus_per_island=1, parallel_queues=_Q)
    _, opt = dp_placement(g, plat)
    # A chain is one linear segment: the hybrid refiner should recover the
    # exact optimum from any start.
    _, lat = hybrid_placement(g, np.ones(8, int), plat)
    assert lat == pytest.approx(opt, rel=1e-9)


# ----------------------------------------------- device head + capacity mask

def _search(graph, platform, head, episodes=4):
    cfg = HSDAGConfig(num_devices=platform.num_devices, head=head,
                      max_episodes=episodes, update_timestep=2,
                      batch_chains=4, seed=0)
    arrays = extract_features(graph, FeatureConfig(d_pos=16))
    return HSDAG(cfg).search(graph, arrays, platform=platform,
                             rng=jax.random.PRNGKey(0))


@pytest.mark.slow
@pytest.mark.parametrize("build", [
    lambda: ring(devices=2, parallel_queues=_Q),
    lambda: nvlink_island(islands=2, gpus_per_island=2, parallel_queues=_Q),
    lambda: torus(rows=2, cols=4, parallel_queues=_Q),
])
def test_device_head_trains_and_decodes(build):
    plat = build()
    g = series_parallel_dag(target_nodes=12, seed=2)
    res = _search(g, plat, "device")
    assert res.best_placement.shape == (g.num_nodes,)
    assert set(np.unique(res.best_placement)) <= set(range(plat.num_devices))
    assert np.isfinite(res.best_latency)
    # Never below the provable optimum on this SP workload (the engine
    # scores in f32, so allow its rounding against the f64 DP value).
    opt = dp_optimal(g, plat)
    assert res.best_latency >= opt.latency * (1 - 1e-5)


def test_device_head_requires_platform():
    g = series_parallel_dag(target_nodes=8, seed=0)
    arrays = extract_features(g, FeatureConfig(d_pos=16))
    cfg = HSDAGConfig(num_devices=4, head="device", max_episodes=2,
                      update_timestep=1, batch_chains=2, seed=0)
    with pytest.raises(ValueError, match="platform"):
        HSDAG(cfg).search(g, arrays, rng=jax.random.PRNGKey(0))


def test_config_rejects_unknown_head():
    with pytest.raises(ValueError, match="head"):
        HSDAGConfig(num_devices=2, head="bogus")


def test_policy_action_mask_forces_feasible_devices():
    rng = jax.random.PRNGKey(0)
    hidden, slots, dev = 16, 6, 4
    plat = nvlink_island(islands=2, gpus_per_island=2)
    feats = device_feature_table(plat)
    params = policy_init(rng, hidden, dev, head="device",
                         dev_feat_dim=feats.shape[1])
    pooled = jax.random.normal(jax.random.PRNGKey(1), (slots, hidden))
    labels = np.arange(slots, dtype=np.int32)
    active = np.ones(slots, bool)
    mask = np.zeros((slots, dev), bool)
    mask[:, 2] = True                      # only device 2 fits anywhere
    out = policy_apply(params, pooled, active, labels,
                       jax.random.PRNGKey(2), dev_feats=feats,
                       action_mask=mask)
    assert np.all(np.asarray(out.fine_placement) == 2)


def test_policy_all_infeasible_mask_falls_back_to_unmasked():
    rng = jax.random.PRNGKey(0)
    hidden, slots, dev = 16, 4, 3
    plat = ring(devices=dev)
    feats = device_feature_table(plat)
    params = policy_init(rng, hidden, dev, head="device",
                         dev_feat_dim=feats.shape[1])
    pooled = jax.random.normal(jax.random.PRNGKey(1), (slots, hidden))
    labels = np.arange(slots, dtype=np.int32)
    out = policy_apply(params, pooled, np.ones(slots, bool), labels,
                       jax.random.PRNGKey(2), dev_feats=feats,
                       action_mask=np.zeros((slots, dev), bool))
    assert np.all(np.isfinite(np.asarray(out.logits)))
    assert np.isfinite(float(out.logp))


def test_sim_arrays_fit_ok_reflects_capacities():
    g = series_parallel_dag(target_nodes=8, seed=0)
    plat = nvlink_island(islands=2, gpus_per_island=1, island_scale=0.5,
                         mem_capacity=1e4)        # island 1: 5e3 bytes
    sa = sim_arrays(g, plat)
    byts = np.array([n.bytes_out for n in g.nodes])
    expect = byts[:, None] <= np.array([d.mem_capacity
                                        for d in plat.devices])[None, :]
    assert np.array_equal(np.asarray(sa.fit_ok), expect)


# ------------------------------------------------------- CLI platform spec

def test_parse_platform_spec_roundtrip():
    from repro.api import parse_platform_spec
    name, args = parse_platform_spec(
        "nvlink_island:islands=2:gpus_per_island=4:island_scale=0.5")
    assert name == "nvlink_island"
    assert args == {"islands": 2, "gpus_per_island": 4, "island_scale": 0.5}
    assert parse_platform_spec("paper") == ("paper", {})


@pytest.mark.parametrize("spec,match", [
    ("", r"segment 0 \(''\)"),
    ("bogus_platform", r"segment 0 \('bogus_platform'\): unknown"),
    ("ring:devices", r"segment 1 \('devices'\)"),
    ("ring::devices=4", r"segment 1 \(''\)"),
    ("ring:=4", r"segment 1"),
    ("ring:devices=4:devices=8", r"segment 2 \('devices=8'\): duplicate"),
])
def test_parse_platform_spec_errors_name_segment(spec, match):
    from repro.api import parse_platform_spec
    with pytest.raises(ValueError, match=match):
        parse_platform_spec(spec)


def test_registry_builds_topology_platforms():
    from repro.api import PlacementSpec, build_platform
    spec = PlacementSpec(workload="benchmark", platform="torus",
                         platform_args={"rows": 2, "cols": 2})
    assert build_platform(spec).num_devices == 4


def test_spec_head_validation():
    from repro.api import PlacementSpec
    with pytest.raises(ValueError, match="head"):
        PlacementSpec(workload="benchmark", head="bogus")
    spec = PlacementSpec(workload="benchmark", head="device")
    assert spec.resolved_config().head == "device"
