"""ShardedRolloutEngine parity contract (PR-6 tentpole).

* mesh 1×1 trains **bit-for-bit** equal to the unsharded
  DynamicRolloutEngine (every psum is an identity, the shard body is the
  same jaxpr — ``build_window_fns`` is shared).
* Any real factorization (2×2, 4×2) matches the unsharded run to ≤1e-5 on
  final parameters — the only delta is the in-mesh float32 replay-weights
  kernel vs the host float64 path.

Multi-device runs follow DESIGN.md §8: subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process keeps one device).
"""
import numpy as np
import pytest

from test_distributed import run_with_devices

_CFG_KW = dict(num_devices=2, hidden_channel=16, max_episodes=3,
               update_timestep=2, batch_chains=4)
_SPEC = "synthetic:family=mixed:count=8:size=14:seed=0"


def _train(mesh_shape=None, **kw):
    import jax
    from repro.core.costmodel import paper_platform
    from repro.core.hsdag import HSDAGConfig
    from repro.core.train.curriculum import CurriculumTrainer
    from repro.graphs import build_corpus

    trainer = CurriculumTrainer(
        HSDAGConfig(**_CFG_KW), max_buckets=2, graphs_per_episode=4,
        mesh_shape=mesh_shape, **kw)
    res = trainer.train_corpus(build_corpus(_SPEC),
                               platform=paper_platform())
    return res, [np.asarray(l) for l in jax.tree.leaves(res.params)]


@pytest.mark.slow
def test_mesh_1x1_bitwise_training():
    """mesh=1×1 is the unsharded run, bit for bit (params, bests, greedy)."""
    ref, ref_leaves = _train(mesh_shape=None)
    got, got_leaves = _train(mesh_shape=(1, 1))
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(ref.best_latencies, got.best_latencies)
    np.testing.assert_array_equal(ref.greedy_latencies, got.greedy_latencies)


def test_fused_weights_match_host_pergraph():
    """window_weights (in-mesh f32) ≈ the host f64 pergraph+step_weights
    path, for both discount modes and with/without time-normalization."""
    from repro.core.hsdag import HSDAGConfig
    from repro.core.reinforce import step_weights
    from repro.core.sim import ShardedRolloutEngine

    eng = ShardedRolloutEngine(lambda *a, **k: None, HSDAGConfig(),
                               mesh_shape=(1, 1))
    rng = np.random.default_rng(0)
    rewards = rng.standard_normal((5, 2, 4)).astype(np.float32) * 3.0

    for rtg in (False, True):
        for norm in (False, True):
            got = np.asarray(eng.window_weights(
                rewards, gamma=0.97, reward_to_go=rtg, normalize=norm,
                reward_norm="pergraph"))
            r = rewards.astype(np.float64)
            mean = r.mean(axis=(0, 2), keepdims=True)
            std = r.std(axis=(0, 2), keepdims=True)
            w_gbt = step_weights(
                np.transpose((r - mean) / (std + 1e-8), (1, 2, 0)),
                0.97, reward_to_go=rtg, normalize=norm)
            want = np.transpose(w_gbt, (2, 0, 1))
            np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)

    # reward_norm="none": no standardization at all
    got = np.asarray(eng.window_weights(
        rewards, gamma=1.0, reward_to_go=False, normalize=False,
        reward_norm="none"))
    want = np.transpose(step_weights(
        np.transpose(rewards.astype(np.float64), (1, 2, 0)), 1.0,
        reward_to_go=False, normalize=False), (2, 0, 1))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_mesh_needs_devices():
    """A mesh larger than the visible device set names the XLA_FLAGS fix."""
    from repro.core.sim import make_rollout_mesh
    with pytest.raises(ValueError, match="host_platform_device_count"):
        make_rollout_mesh(2, 2)


def test_mesh_tiling_validation():
    """G/B not divisible by the mesh axes raise before any device work."""
    from repro.core.costmodel import paper_platform
    from repro.core.hsdag import HSDAGConfig
    from repro.core.train.curriculum import CurriculumTrainer
    from repro.graphs import build_corpus

    graphs = build_corpus("synthetic:count=4:size=12:seed=1")
    t = CurriculumTrainer(HSDAGConfig(**_CFG_KW), graphs_per_episode=3,
                          mesh_shape=(2, 1))
    with pytest.raises(ValueError, match="does not tile the mesh 'graphs'"):
        t.train_corpus(graphs, platform=paper_platform())
    t = CurriculumTrainer(HSDAGConfig(**dict(_CFG_KW, batch_chains=3)),
                          graphs_per_episode=2, mesh_shape=(1, 2))
    with pytest.raises(ValueError, match="does not tile the mesh 'chains'"):
        t.train_corpus(graphs, platform=paper_platform())
    with pytest.raises(ValueError, match="must be positive"):
        CurriculumTrainer(HSDAGConfig(**_CFG_KW), mesh_shape=(0, 2))
    with pytest.raises(ValueError, match="unknown update mode"):
        CurriculumTrainer(HSDAGConfig(**_CFG_KW), update="psum")


@pytest.mark.slow
def test_sharded_parity_multidevice():
    """2×2 and 4×2 meshes match the unsharded run to ≤1e-5 on final params
    (8 virtual host devices; the weights kernel is the only f32 delta)."""
    out = run_with_devices("""
        import jax, numpy as np
        from repro.core.costmodel import paper_platform
        from repro.core.hsdag import HSDAGConfig
        from repro.core.train.curriculum import CurriculumTrainer
        from repro.graphs import build_corpus

        SPEC = "synthetic:family=mixed:count=8:size=14:seed=0"
        cfg = HSDAGConfig(num_devices=2, hidden_channel=16, max_episodes=2,
                          update_timestep=2, batch_chains=4)

        def leaves(mesh_shape):
            tr = CurriculumTrainer(cfg, max_buckets=2, graphs_per_episode=4,
                                   mesh_shape=mesh_shape)
            res = tr.train_corpus(build_corpus(SPEC),
                                  platform=paper_platform())
            return [np.asarray(l) for l in jax.tree.leaves(res.params)]

        ref = leaves(None)
        for shape in ((2, 2), (4, 2)):
            got = leaves(shape)
            worst = max(float(np.max(np.abs(a - b)))
                        for a, b in zip(ref, got))
            assert worst <= 1e-5, (shape, worst)
            print("mesh", shape, "max|dparam|", worst)
        print("OK")
    """, n=8, timeout=600)
    assert "OK" in out
