"""Distribution tests needing >1 device run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count (set before jax init;
the main test process keeps 1 device, per DESIGN.md §8)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 360) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharding_rules_resolve():
    from repro.distributed.sharding import logical_spec, with_rules
    from jax.sharding import PartitionSpec
    rules = with_rules({"kv_heads": None})
    spec = logical_spec(("batch", "act_seq", "heads", "head_dim"), rules)
    assert spec == PartitionSpec(("pod", "data"), None, "model", None)
    spec2 = logical_spec(("batch", None, "kv_heads", None), rules)
    assert spec2 == PartitionSpec(("pod", "data"), None, None, None)


def test_use_rules_restores_on_exception():
    """The context restores mesh+rules even when the body raises."""
    from repro.distributed.sharding import current_mesh, use_rules
    from repro.distributed import sharding

    assert current_mesh() is None
    with pytest.raises(RuntimeError):
        with use_rules("outer-mesh", {"batch": "data"}):
            assert current_mesh() == "outer-mesh"
            with pytest.raises(RuntimeError):
                with use_rules("inner-mesh", {"batch": None}):
                    assert current_mesh() == "inner-mesh"
                    raise RuntimeError("inner boom")
            # inner context unwound cleanly, outer still active
            assert current_mesh() == "outer-mesh"
            assert sharding._CTX.rules["batch"] == "data"
            raise RuntimeError("outer boom")
    assert current_mesh() is None
    assert sharding._CTX.rules is None


def test_use_rules_bad_rules_leave_context_intact():
    """A rules mapping that explodes during merge must not half-activate."""
    from repro.distributed.sharding import current_mesh, use_rules

    from collections.abc import Mapping

    class BoomMapping(Mapping):
        def __getitem__(self, k):
            raise RuntimeError("bad rules")

        def __iter__(self):
            return iter(["batch"])

        def __len__(self):
            return 1

        def keys(self):
            raise RuntimeError("bad rules")

    with use_rules("outer-mesh"):
        with pytest.raises(RuntimeError, match="bad rules"):
            with use_rules("inner-mesh", BoomMapping()):
                pass                             # pragma: no cover
        assert current_mesh() == "outer-mesh"
    assert current_mesh() is None


def test_rollout_rules_resolve():
    from jax.sharding import PartitionSpec
    from repro.distributed.sharding import ROLLOUT_RULES, logical_spec
    spec = logical_spec(("time", "graphs", "chains"), ROLLOUT_RULES)
    assert spec == PartitionSpec(None, "graphs", "chains")


def test_mesh_axis_filtering():
    """'pod' is dropped when the mesh lacks that axis (single-pod mode)."""
    out = run_with_devices("""
        import jax, numpy as np
        from jax.sharding import Mesh, PartitionSpec
        from repro.distributed.sharding import logical_spec, with_rules
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        spec = logical_spec(("batch", "act_embed"), with_rules({}), mesh)
        assert spec == PartitionSpec(("data",), None), spec
        print("OK")
    """, n=8)
    assert "OK" in out


def test_quantize_roundtrip_error_bound():
    import jax.numpy as jnp
    from repro.distributed.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32) * 3.0)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    # per-block max error ≤ scale/2 = amax/254
    blocks = np.asarray(x).reshape(-1, 256)
    bound = np.abs(blocks).max(1) / 254.0 + 1e-7
    assert np.all(err.reshape(-1, 256).max(1) <= bound * 1.01)


def test_compressed_psum_matches_mean():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.compression import compressed_psum_mean
        n_dev = 8
        mesh = Mesh(np.array(jax.devices()), ("data",))
        rng = np.random.default_rng(0)
        xs = rng.standard_normal((n_dev, 8 * 256 * n_dev)).astype(np.float32)

        def f(x):
            x = x.reshape(-1)
            return compressed_psum_mean(x, "data", n_dev)

        g = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                      check_rep=False)
        got = np.asarray(g(jnp.asarray(xs)))
        want = xs.mean(0)
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 2e-2, rel         # int8 wire error
        print("OK", rel)
    """, n=8)
    assert "OK" in out


def test_compressed_allreduce_error_feedback_converges():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.compression import compressed_allreduce_tree
        n_dev = 4
        mesh = Mesh(np.array(jax.devices())[:4], ("data",))
        rng = np.random.default_rng(1)
        g_global = rng.standard_normal((4, 2048)).astype(np.float32)

        def f(x, err):
            grads = {"w": x.reshape(-1)}
            red, new_err = compressed_allreduce_tree(
                grads, "data", n_dev, err.reshape(-1))
            return red["w"], new_err.reshape(1, -1)

        fn = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P(), P("data")), check_rep=False)
        err = jnp.zeros((4, 2048), jnp.float32)
        # accumulated mean over steps approaches the exact mean (EF property)
        acc = np.zeros(2048, np.float32)
        for step in range(8):
            red, err = fn(jnp.asarray(g_global), err)
            acc += np.asarray(red)
        want = g_global.mean(0) * 8
        rel = np.abs(acc - want).max() / np.abs(want).max()
        assert rel < 5e-3, rel        # EF drives accumulated error down
        print("OK", rel)
    """, n=4)
    assert "OK" in out


def test_pipeline_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_apply
        S = 4
        mesh = Mesh(np.array(jax.devices())[:S], ("pod",))
        rng = jax.random.PRNGKey(0)
        d = 16
        # per-stage params: a dense layer each
        w = jax.random.normal(rng, (S, d, d)) / np.sqrt(d)

        def stage_fn(p, x):
            return jnp.tanh(x @ p)

        M = 6
        xs = jax.random.normal(jax.random.fold_in(rng, 1), (M, 3, d))
        out = pipeline_apply(stage_fn, w, xs, mesh=mesh, axis="pod")
        # sequential reference
        ref = xs
        for s in range(S):
            ref = jnp.tanh(ref @ w[s])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        # gradients flow through the pipeline (backward = GPipe)
        def loss(w_):
            return jnp.sum(pipeline_apply(stage_fn, w_, xs, mesh=mesh,
                                          axis="pod") ** 2)
        def loss_ref(w_):
            r = xs
            for s in range(S):
                r = jnp.tanh(r @ w_[s])
            return jnp.sum(r ** 2)
        g1 = jax.grad(loss)(w)
        g2 = jax.grad(loss_ref)(w)
        gerr = float(jnp.max(jnp.abs(g1 - g2)))
        assert gerr < 1e-4, gerr
        print("OK", err, gerr)
    """, n=4)
    assert "OK" in out


def test_sharded_matmul_matches_dense():
    """shard_map TP matmul with psum == dense reference."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = Mesh(np.array(jax.devices()), ("model",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))

        def f(x_l, w_l):     # x (4, 64/8), w (64/8, 32): contract + psum
            return jax.lax.psum(x_l @ w_l, "model")

        g = shard_map(f, mesh=mesh, in_specs=(P(None, "model"),
                                              P("model", None)),
                      out_specs=P())
        got = g(x, w)
        err = float(jnp.max(jnp.abs(got - x @ w)))
        assert err < 1e-4, err
        print("OK")
    """, n=8)
    assert "OK" in out


def test_straggler_watchdog():
    from repro.distributed import StragglerWatchdog
    wd = StragglerWatchdog(threshold=2.0, warmup_steps=2)
    for i in range(6):
        assert not wd.record(i, 1.0)
    assert wd.record(6, 5.0)          # 5× the EMA → flagged
    assert wd.flagged[0][0] == 6
    assert not wd.record(7, 1.0)      # baseline not poisoned


def test_choose_mesh_shape_shrinks_data_axis():
    from repro.distributed import choose_mesh_shape
    assert choose_mesh_shape(256, 16) == (16, 16)
    assert choose_mesh_shape(240, 16) == (15, 16)
    assert choose_mesh_shape(250, 16) == (125, 2)   # degrade model parallel
    assert choose_mesh_shape(7, 16) == (7, 1)
