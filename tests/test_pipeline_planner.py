"""Integration: HSDAG placement plan → shard_map pipeline execution.

The paper's planner decides the stage split of a layer stack; the pipeline
module executes that split over the pod/stage mesh axis.  This test runs the
full chain on 4 virtual devices in a subprocess and checks numerics against
sequential execution.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_plan_driven_pipeline_matches_sequential():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.planner import plan_stages, _monotone_projection
        from repro.core.graph import topological_order
        from repro.core.hsdag import HSDAGConfig
        from repro.distributed.pipeline import pipeline_apply
        from repro.models import ModelConfig

        S = 4              # pipeline stages == devices
        L = 8              # uniform layer stack
        d = 32

        # 1. HSDAG plans the split of a uniform dense stack across 4 stages
        cfg = ModelConfig(name="plan-demo", n_layers=L, d_model=d, n_heads=4,
                          n_kv_heads=4, d_ff=64, vocab_size=64, remat=False,
                          dtype="float32")
        plan = plan_stages(cfg, seq_len=64, batch=4, num_stages=S,
                           hsdag_cfg=HSDAGConfig(num_devices=S,
                                                 max_episodes=3,
                                                 update_timestep=6,
                                                 hidden_channel=32))
        order = topological_order(plan.graph)
        stages = plan.stage_of_node[order]
        assert np.all(np.diff(stages) >= 0)          # contiguous stages

        # 2. map the plan onto executable per-stage layer slices.  The
        # shard_map pipeline needs equal-size stages (one program, different
        # params); production pads — here we balance the boundary.
        w = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) / np.sqrt(d)
        per_stage = L // S
        stage_w = w.reshape(S, per_stage, d, d)

        def stage_fn(p, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(body, x, p)
            return h

        mesh = Mesh(np.array(jax.devices())[:S], ("pod",))
        xs = jax.random.normal(jax.random.PRNGKey(1), (6, 3, d))
        out = pipeline_apply(stage_fn, stage_w, xs, mesh=mesh, axis="pod")

        ref = xs
        for i in range(L):
            ref = jnp.tanh(ref @ w[i])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("OK", err, "boundaries:", plan.boundaries)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
