"""Tests for roofline analysis: HLO collective parsing + term math."""
import numpy as np
import pytest

from repro.launch.roofline import (HW, collective_bytes_from_hlo,
                                   roofline_terms)

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %ag = f32[256,1024]{1,0} all-gather(f32[16,1024]{1,0} %p0), replica_groups={}
  %ar = bf16[512,512]{1,0} all-reduce(bf16[512,512]{1,0} %x), to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(f32[32,64]{1,0} %y), dimensions={0}
  %a2a = s8[64,128]{1,0} all-to-all(s8[64,128]{1,0} %z), dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %w), source_target_pairs={{0,1}}
  %ars = bf16[256]{0} all-reduce-start(bf16[256]{0} %q), to_apply=%add
  %dot = f32[16,16]{1,0} dot(f32[16,32]{1,0} %a, f32[32,16]{1,0} %b)
}
"""


def test_collective_bytes_parser():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    # all-gather: result 256*1024*4 = 1,048,576 (max of operand/result)
    assert out["all-gather"] == 256 * 1024 * 4
    # all-reduce: 2× for ring phases; plus the -start op (256*2 bytes)
    assert out["all-reduce"] == 2 * (512 * 512 * 2) + 2 * (256 * 2)
    # reduce-scatter: operand 32*64*4 is the max shape
    assert out["reduce-scatter"] == 32 * 64 * 4
    assert out["all-to-all"] == 64 * 128 * 1
    assert out["collective-permute"] == 8 * 8 * 4
    assert out["counts"]["all-reduce"] == 2
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_dot_not_counted():
    out = collective_bytes_from_hlo(
        "%dot = f32[16,16]{1,0} dot(f32[16,32]{1,0} %a, f32[32,16]{1,0} %b)")
    assert out["total"] == 0


def test_roofline_terms_math():
    cell = {
        "arch": "qwen1.5-0.5b", "shape": "train_4k", "mesh": "16x16",
        "num_devices": 256,
        "flops": 197e12,                   # exactly 1 s of compute/device
        "bytes_accessed": 819e9 * 0.5,     # 0.5 s of HBM
        "collectives": {"total": 50e9 * 0.25},   # 0.25 s of ICI
        "active_params": 0.46e9,
    }
    r = roofline_terms(cell)
    assert r["dominant"] == "compute"
    np.testing.assert_allclose(r["compute_s"], 1.0)
    np.testing.assert_allclose(r["memory_s"], 0.5)
    np.testing.assert_allclose(r["collective_s"], 0.25)
    # useful ratio: 6·N·tokens / (flops × chips)
    tokens = 4096 * 256
    expect = 6 * 0.46e9 * tokens / (197e12 * 256)
    np.testing.assert_allclose(r["useful_ratio"], expect)
    # roofline fraction = useful time / bound time
    np.testing.assert_allclose(
        r["roofline_fraction"],
        (6 * 0.46e9 * tokens / (256 * 197e12)) / 1.0)


def test_roofline_skips_incomplete():
    assert roofline_terms({"skipped": "x"}) is None
    assert roofline_terms({"flops": None}) is None


def test_dryrun_results_sane_if_present():
    """Validate real sweep artifacts when they exist (integration)."""
    import glob
    import json
    files = glob.glob("results/dryrun/*__16x16.json")
    if not files:
        pytest.skip("no dry-run artifacts yet")
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            continue
        assert d["flops"] and d["flops"] > 0, f
        assert d["memory"]["temp_size_in_bytes"] >= 0, f
        r = roofline_terms(d)
        assert r and r["bound_s"] > 0, f
        assert 0 < r["useful_ratio"] < 10, (f, r["useful_ratio"])
