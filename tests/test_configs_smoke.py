"""Per-architecture smoke tests (deliverable f).

Each assigned arch gets a REDUCED same-family config that runs a real
forward + train step + decode step on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get, input_specs, SHAPES
from repro.models import (TrainState, decode_step, forward, init_params,
                          make_train_step, prefill)
from repro.optim import adamw

ARCHS = all_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get(arch).smoke_config
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    kwargs = {}
    if cfg.vision_tokens:
        kwargs["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.vision_tokens, cfg.d_model))
    logits = forward(params, cfg, toks, ssd_chunk=8, **kwargs)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    opt = adamw(1e-3)
    state = TrainState(params, opt.init(params), jnp.int32(0))
    step = jax.jit(make_train_step(cfg, opt, ssd_chunk=8))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1), **kwargs}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    state, m2 = step(state, batch)
    assert float(m2["loss"]) < float(metrics["loss"]) + 1.0  # sane scale


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_consistency(arch):
    """Serve path: prefill + decode must equal the full forward."""
    cfg = get(arch).smoke_config
    if cfg.vision_tokens:
        pytest.skip("decode smoke uses pure-token prompts")
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, prompt, total = 2, 8, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, total), 0,
                              cfg.vocab_size)
    full = forward(params, cfg, toks, ssd_chunk=4)
    _, caches = prefill(params, cfg, toks[:, :prompt], ssd_chunk=4,
                        max_len=total)
    for t in range(prompt, total):
        lg, caches = decode_step(params, cfg, toks[:, t:t + 1], caches,
                                 jnp.int32(t))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 2e-3, (arch, t, err)


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_all_shapes(arch):
    """Every non-skipped (arch × shape) cell has well-formed input specs."""
    spec = get(arch)
    for shape_name, shp in SHAPES.items():
        if shape_name in spec.skip:
            continue
        cell = input_specs(arch, shape_name)
        specs = cell["specs"]
        assert specs["tokens"].shape[0] == shp.global_batch
        if shp.kind == "decode":
            assert specs["tokens"].shape == (shp.global_batch, 1)
            # KV cache depth covers seq_len (or the SWA window)
            cfg = spec.config
            leaves = jax.tree.leaves(specs["caches"])
            assert leaves, arch
        else:
            assert specs["tokens"].shape[1] == shp.seq_len


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_within_family_budget(arch):
    """Full config's analytic param count is within 10% of the advertised
    size (catches config-entry typos)."""
    expected = {
        "mixtral-8x22b": 141e9, "olmoe-1b-7b": 6.9e9,
        "command-r-plus-104b": 104e9, "phi3-mini-3.8b": 3.8e9,
        "h2o-danube-1.8b": 1.8e9, "qwen1.5-0.5b": 0.46e9,
        "mamba2-130m": 0.13e9, "internvl2-76b": 70e9,
        "jamba-1.5-large-398b": 398e9, "musicgen-medium": 1.4e9,
    }[arch]
    n = get(arch).config.num_params()
    assert abs(n - expected) / expected < 0.10, (arch, n, expected)


def test_long_500k_skips_documented():
    """Exactly the pure full-attention archs skip long_500k."""
    skippers = {a for a in ARCHS if "long_500k" in get(a).skip}
    assert skippers == {"olmoe-1b-7b", "command-r-plus-104b",
                        "phi3-mini-3.8b", "qwen1.5-0.5b", "internvl2-76b",
                        "musicgen-medium"}
    for a in ARCHS - skippers if isinstance(ARCHS, set) else \
            [x for x in ARCHS if x not in skippers]:
        assert get(a).config.is_subquadratic, a
