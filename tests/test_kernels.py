"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
oracles, interpret=True (the TPU kernel body executed on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gcn_spmm import gcn_aggregate
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA
    (1, 8, 1, 128, 128),     # MQA
    (1, 2, 2, 200, 64),      # non-divisible seq
])
def test_flash_attention_sweep(b, h, kv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shape_invariance(bq, bk):
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64, 128), (2, 200, 256), (1, 1, 512),
                                   (3, 70, 128)])
def test_rmsnorm_sweep(shape, dtype):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, shape, dtype)
    scale = (jax.random.normal(jax.random.fold_in(k, 1),
                               (shape[-1],)) + 1.0).astype(jnp.float32)
    out = rmsnorm(x, scale, interpret=True, block_rows=64)
    expect = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


# ----------------------------------------------------------------- gcn spmm
@pytest.mark.parametrize("v,f,bm", [(128, 64, 64), (200, 96, 64),
                                    (728, 128, 128), (37, 19, 16),
                                    (396, 128, 128)])
def test_gcn_aggregate_sweep(v, f, bm):
    k = jax.random.PRNGKey(0)
    adj = (jax.random.uniform(jax.random.fold_in(k, 2), (v, v)) < 0.05
           ).astype(jnp.float32)
    adj = adj * (1 - jnp.eye(v))
    h = jax.random.normal(jax.random.fold_in(k, 3), (v, f))
    out = gcn_aggregate(adj, h, interpret=True, block_m=bm, block_n=64,
                        block_k=bm)
    expect = ref.gcn_aggregate_ref(adj, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_gcn_matches_model_encoder_normalization():
    """Kernel must agree with the encoder's normalize_adjacency (Eq. 6)."""
    from repro.core.gnn import normalize_adjacency
    k = jax.random.PRNGKey(5)
    v = 96
    adj = (jax.random.uniform(k, (v, v)) < 0.08).astype(jnp.float32)
    adj = adj * (1 - jnp.eye(v))
    h = jax.random.normal(jax.random.fold_in(k, 1), (v, 32))
    out = gcn_aggregate(adj, h, interpret=True, block_m=32, block_n=32,
                        block_k=32)
    expect = normalize_adjacency(adj) @ h
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,c,h,p,n", [(2, 5, 3, 16, 32), (1, 16, 8, 64, 128),
                                       (3, 1, 2, 8, 16)])
def test_ssd_scan_sweep(b, c, h, p, n):
    k = jax.random.PRNGKey(0)
    dec = jax.random.uniform(k, (b, c, h), minval=0.3, maxval=0.999)
    dbx = jax.random.normal(jax.random.fold_in(k, 1), (b, c, h, p, n))
    before, final = ssd_scan(dec, dbx, interpret=True)
    rb, rf = ref.ssd_scan_ref(dec, dbx)
    np.testing.assert_allclose(np.asarray(before), np.asarray(rb),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(final), np.asarray(rf),
                               rtol=1e-6, atol=1e-6)


def test_ssd_scan_matches_model_ssm():
    """Kernel recurrence == the lax.scan inside models/ssm.py."""
    import jax
    k = jax.random.PRNGKey(7)
    dec = jax.random.uniform(k, (2, 8, 4), minval=0.5, maxval=0.99)
    dbx = jax.random.normal(jax.random.fold_in(k, 1), (2, 8, 4, 32, 16))
    before, final = ssd_scan(dec, dbx, interpret=True)
    rb, rf = ref.ssd_scan_ref(dec, dbx)
    np.testing.assert_allclose(np.asarray(before), np.asarray(rb), rtol=1e-6)
