import numpy as np
import pytest

from repro.core import CompGraph

# ---------------------------------------------------------------- hypothesis
# ``hypothesis`` is a test extra (pyproject ``[test]``), not a runtime dep.
# Mixed test modules import the stand-ins below when it is missing so their
# example-based tests still run and only the property tests skip.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal environments
    HAVE_HYPOTHESIS = False

    def _skip_property_test(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    given = settings = _skip_property_test

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *_a, **_k: None

    st = _AnyStrategy()


def make_diamond() -> CompGraph:
    """Small branchy DAG used across unit tests."""
    g = CompGraph("diamond")
    g.add_op("in", "Parameter", output_shape=(1, 16), flops=0, bytes_out=64)
    g.add_op("a", "MatMul", ["in"], (1, 32), flops=2e6, bytes_out=128)
    g.add_op("b", "MatMul", ["in"], (1, 32), flops=4e6, bytes_out=128)
    g.add_op("relu_a", "ReLU", ["a"], (1, 32), flops=32, bytes_out=128)
    g.add_op("relu_b", "ReLU", ["b"], (1, 32), flops=32, bytes_out=128)
    g.add_op("cat", "Concat", ["relu_a", "relu_b"], (1, 64), flops=0,
             bytes_out=256)
    g.add_op("out", "MatMul", ["cat"], (1, 8), flops=1e6, bytes_out=32)
    return g


@pytest.fixture
def diamond() -> CompGraph:
    return make_diamond()


def random_dag(rng: np.random.Generator, n: int, p: float = 0.15) -> CompGraph:
    """Random DAG: edge (i, j) for i<j with prob p (guaranteed acyclic)."""
    g = CompGraph(f"rand{n}")
    types = ["MatMul", "ReLU", "Concat", "Convolution", "Add"]
    for i in range(n):
        g.add_op(f"n{i}", types[int(rng.integers(len(types)))],
                 output_shape=(1, int(rng.integers(1, 64))),
                 flops=float(rng.integers(1, 1_000_000)),
                 bytes_out=float(rng.integers(4, 4096)))
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p:
                g.add_edge(i, j)
    return g
