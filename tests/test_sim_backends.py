"""Simulation-engine layer: backend registry, parity, and the level kernel.

The parity contract: device queues make the list schedule order-sensitive
(level-major vs heap-Kahn retire order shifts Inception's makespan by ~20%),
so the retire order is part of each backend's cost model and *all backends
agree (≤1e-5 relative latency) on a common order* — the reference scheduler
takes the order explicitly (``simulate(..., order=)``), the scan kernel runs
it via ``sim_arrays(schedule="level")``, and the level Pallas kernel retires
it natively.  On the default heap-Kahn order, scan vs reference parity is
pinned by tests/test_costmodel_batch.py (unchanged — bit-for-bit PR-1/2).
"""
import numpy as np
import pytest

import jax

from repro.core import (HSDAG, HSDAGConfig, FeatureConfig, backend_names,
                        extract_features, get_backend, paper_platform,
                        simulate, simulate_batch, tpu_stage_platform)
from repro.core.costmodel import pad_sim_arrays, sim_arrays, simulate_jax
from repro.core.sim import RewardPipeline
from repro.graphs import PAPER_BENCHMARKS

from conftest import given, make_diamond, random_dag, settings, st

RTOL = 1e-5


# ------------------------------------------------------------------ registry
def test_registry_has_the_three_backends():
    assert {"reference", "scan", "level"} <= set(backend_names())


def test_get_backend_unknown_raises_with_names():
    with pytest.raises(ValueError) as e:
        get_backend("bogus")
    for name in backend_names():
        assert name in str(e.value)


def test_config_validates_engine_against_registry():
    HSDAGConfig(engine="level")               # registered backend: fine
    HSDAGConfig(engine="scalar")              # loop selector: fine
    with pytest.raises(ValueError) as e:
        HSDAGConfig(engine="bogus")
    for name in backend_names():
        assert name in str(e.value)


# --------------------------------------------------- three-backend agreement
def _assert_backends_agree(g, placements, plat):
    """All three backends score the same placements on the *level* schedule
    (the common order) to ≤1e-5 relative latency/reward."""
    placements = np.atleast_2d(np.asarray(placements))
    level = get_backend("level")
    prep = level.prepare(g, plat)
    order = level.schedule_order(prep)
    res_level = level.simulate_batch(prep, placements)
    # reference, replaying the same retire order
    ref = get_backend("reference")
    res_ref = ref.simulate_batch(ref.prepare(g, plat, order=order),
                                 placements)
    # scan kernel on the level-schedule arrays
    sa = sim_arrays(g, plat, schedule="level")
    np.testing.assert_array_equal(np.asarray(sa.order, np.int64), order)
    res_scan = np.asarray(
        [float(simulate_jax(sa, p.astype(np.int32)).latency)
         for p in placements])
    np.testing.assert_allclose(res_level.latency, res_ref.latency, rtol=RTOL)
    np.testing.assert_allclose(res_scan, res_ref.latency, rtol=RTOL)
    np.testing.assert_allclose(res_level.reward, res_ref.reward, rtol=RTOL)
    np.testing.assert_allclose(res_level.transfer_time, res_ref.transfer_time,
                               rtol=1e-4, atol=1e-12)
    np.testing.assert_allclose(res_level.per_device_busy,
                               res_ref.per_device_busy, rtol=1e-4)
    assert np.array_equal(res_level.oom, res_ref.oom)


@pytest.mark.parametrize("name", sorted(PAPER_BENCHMARKS))
def test_backends_agree_on_paper_graphs(name):
    """Acceptance: the level Pallas backend matches the reference scheduler
    to ≤1e-5 relative latency on every Table-2 graph (interpret=True), and
    the scan kernel agrees on the same schedule."""
    g = PAPER_BENCHMARKS[name]()
    rng = np.random.default_rng(0)
    placements = rng.integers(0, 2, size=(3, g.num_nodes))
    _assert_backends_agree(g, placements, paper_platform())


def test_backends_agree_on_diamond_and_random_dags():
    rng = np.random.default_rng(7)
    plat = paper_platform()
    _assert_backends_agree(make_diamond(), rng.integers(0, 2, (8, 7)), plat)
    for n in (5, 17, 40):
        g = random_dag(rng, n, p=0.2)
        _assert_backends_agree(g, rng.integers(0, 2, (6, n)), plat)


def test_backends_agree_multi_device():
    rng = np.random.default_rng(3)
    g = random_dag(rng, 30, p=0.15)
    _assert_backends_agree(g, rng.integers(0, 4, (6, 30)),
                           tpu_stage_platform(num_stages=4))


@settings(max_examples=12, deadline=None)
@given(st.integers(3, 20), st.integers(0, 500))
def test_property_backends_agree_random_dags(n, seed):
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n, p=0.25)
    plat = paper_platform() if seed % 2 == 0 else tpu_stage_platform(2)
    _assert_backends_agree(g, rng.integers(0, 2, (3, n)), plat)


# --------------------------------------------------------- padded level sims
@pytest.mark.parametrize("extra", [3, 160])
def test_level_backend_padding_is_inert(extra):
    """Padded SimArrays (incl. V_max ≫ V) leave the level kernel's makespan
    bitwise unchanged — pad slots never enter the level tables."""
    from repro.core.sim.level import _level_batch_fn
    from repro.kernels.levelsim import build_level_arrays
    plat = paper_platform()
    g = make_diamond()
    rng = np.random.default_rng(extra)
    placements = rng.integers(0, 2, (4, g.num_nodes)).astype(np.int32)
    sa = sim_arrays(g, plat, schedule="level")
    res = _level_batch_fn()(sa, build_level_arrays(sa), placements,
                            interpret=True)
    sap = pad_sim_arrays(sa, g.num_nodes + extra)
    padded = np.zeros((4, sap.num_nodes), np.int32)
    padded[:, :g.num_nodes] = placements
    resp = _level_batch_fn()(sap, build_level_arrays(sap), padded,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(res.latency),
                                  np.asarray(resp.latency))
    np.testing.assert_array_equal(np.asarray(res.transfer_time),
                                  np.asarray(resp.transfer_time))


def test_level_backend_multi_matches_per_graph():
    """prepare_batch pads every graph to V_max; scoring a (G, B, V_max)
    block equals scoring each graph unpadded."""
    rng = np.random.default_rng(4)
    graphs = [make_diamond(), random_dag(rng, 23, p=0.2),
              random_dag(rng, 11, p=0.3)]
    plat = paper_platform()
    level = get_backend("level")
    preps = level.prepare_batch(graphs, plat, v_max=30)
    B = 3
    placements = np.zeros((len(graphs), B, 30), np.int64)
    for i, g in enumerate(graphs):
        placements[i, :, :g.num_nodes] = rng.integers(0, 2, (B, g.num_nodes))
    res = level.simulate_multi(preps, placements)
    assert res.latency.shape == (3, B)
    for i, g in enumerate(graphs):
        solo = level.simulate_batch(level.prepare(g, plat),
                                    placements[i, :, :g.num_nodes])
        np.testing.assert_array_equal(res.latency[i], solo.latency)


def test_level_backend_rejects_bad_devices():
    g = make_diamond()
    level = get_backend("level")
    prep = level.prepare(g, paper_platform())
    with pytest.raises(ValueError):
        level.simulate_batch(prep, np.full((2, g.num_nodes), 7))
    with pytest.raises(ValueError):
        level.simulate_batch(prep, np.zeros((2, g.num_nodes + 1), int))


# ------------------------------------------------ simulate_batch(sim=) reuse
def test_simulate_batch_accepts_prebuilt_sim_arrays(diamond):
    plat = paper_platform()
    sa = sim_arrays(diamond, plat)
    p = np.random.default_rng(0).integers(0, 2, (4, diamond.num_nodes))
    a = simulate_batch(diamond, p, plat)
    b = simulate_batch(diamond, p, plat, sim=sa)
    np.testing.assert_array_equal(a.latency, b.latency)
    other = random_dag(np.random.default_rng(1), 9, p=0.3)
    with pytest.raises(ValueError):
        simulate_batch(other, np.zeros((1, 9), int), plat, sim=sa)
    # a different graph with the SAME node count must be rejected too —
    # equal shapes would otherwise silently score the wrong graph
    same_size = random_dag(np.random.default_rng(2), diamond.num_nodes,
                           p=0.3)
    sim_arrays(same_size, plat)        # its own cache entry exists
    with pytest.raises(ValueError, match="different graph"):
        simulate_batch(same_size, p, plat, sim=sa)
    # a sim built for a different platform must be rejected, not mis-scored
    with pytest.raises(ValueError, match="different platform"):
        simulate_batch(diamond, p, tpu_stage_platform(2), sim=sa)


# ----------------------------------------------------- engine-driven search
def _cfg(**kw):
    base = dict(num_devices=2, hidden_channel=32, max_episodes=3,
                update_timestep=5)
    base.update(kw)
    return HSDAGConfig(**base)


def test_search_engine_level_end_to_end(diamond):
    """engine="level": rewards come from the Pallas kernel; the reported
    best replays on the reference scheduler under the level-major order."""
    arrays = extract_features(diamond, FeatureConfig(d_pos=8))
    plat = paper_platform()
    cfg = _cfg(batch_chains=4, engine="level")
    res = HSDAG(cfg).search(diamond, arrays, platform=plat,
                            rng=jax.random.PRNGKey(0))
    assert np.isfinite(res.best_latency)
    level = get_backend("level")
    order = level.schedule_order(level.prepare(diamond, plat))
    ref = simulate(diamond, res.best_placement, plat, order=order)
    np.testing.assert_allclose(res.best_latency, ref.latency, rtol=RTOL)


def test_search_engine_reference_matches_host_reward_fn(diamond):
    """engine="reference" is the host scheduler behind the pipeline — its
    trajectory is bit-for-bit a reward_fn wrapping simulate()."""
    arrays = extract_features(diamond, FeatureConfig(d_pos=8))
    plat = paper_platform()

    def reward_fn(p):
        r = simulate(diamond, p, plat)
        return r.reward, r.latency

    ra = HSDAG(_cfg(batch_chains=2)).search(
        diamond, arrays, reward_fn, rng=jax.random.PRNGKey(0),
        engine="batched")
    rb = HSDAG(_cfg(batch_chains=2, engine="reference")).search(
        diamond, arrays, platform=plat, rng=jax.random.PRNGKey(0))
    assert [h["best_latency"] for h in ra.history] == \
        [h["best_latency"] for h in rb.history]
    np.testing.assert_array_equal(ra.best_placement, rb.best_placement)


def test_search_rejects_backend_engine_plus_reward_fn(diamond):
    arrays = extract_features(diamond, FeatureConfig(d_pos=8))
    with pytest.raises(ValueError):
        HSDAG(_cfg(batch_chains=2)).search(
            diamond, arrays, lambda p: (1.0, 1.0), engine="level")


def test_search_and_place_on_edge_free_graph():
    """An edge-free graph pads a masked phantom edge slot in the G=1 batch;
    both the scalar and the batched engine must keep it out of the GPN."""
    from repro.core import CompGraph
    g = CompGraph("loose")
    for i in range(4):
        g.add_op(f"n{i}", "MatMul", output_shape=(1, 8),
                 flops=1e6, bytes_out=64)
    arrays = extract_features(g, FeatureConfig(d_pos=8))
    assert arrays.edges.shape[0] == 0
    plat = paper_platform()
    agent = HSDAG(_cfg(batch_chains=2, max_episodes=1, update_timestep=2))
    res = agent.search(g, arrays, platform=plat, rng=jax.random.PRNGKey(0))
    assert np.isfinite(res.best_latency)
    p = agent.place(arrays)               # scalar path
    assert p.shape == (4,) and set(np.unique(p)) <= {0, 1}


def test_train_multi_rejects_scalar_engine():
    from repro.core import MultiGraphTrainer
    tr = MultiGraphTrainer(_cfg(engine="scalar"))
    with pytest.raises(ValueError, match="no scalar loop"):
        tr.train([make_diamond()], platform=paper_platform(),
                 rng=jax.random.PRNGKey(0))


def test_train_multi_level_backend():
    """Cross-graph training with window-scored Pallas rewards."""
    rng = np.random.default_rng(9)
    graphs = [make_diamond(), random_dag(rng, 9, p=0.3)]
    plat = paper_platform()
    from repro.core import MultiGraphTrainer
    tr = MultiGraphTrainer(_cfg(batch_chains=2, max_episodes=2,
                                update_timestep=3, engine="level"))
    res = tr.train(graphs, platform=plat, rng=jax.random.PRNGKey(0))
    assert np.isfinite(res.best_latencies).all()
    level = get_backend("level")
    for g, p, lat in zip(graphs, res.best_placements, res.best_latencies):
        order = level.schedule_order(level.prepare(g, plat))
        np.testing.assert_allclose(
            simulate(g, p, plat, order=order).latency, lat, rtol=RTOL)


# ------------------------------------------------------- checkpoint metadata
def test_checkpoint_records_engine(tmp_path):
    from repro.checkpoint import policy_manifest
    from repro.core import MultiGraphTrainer
    rng = np.random.default_rng(10)
    graphs = [make_diamond()]
    tr = MultiGraphTrainer(_cfg(batch_chains=2, max_episodes=1,
                                update_timestep=3, engine="scan"))
    tr.train(graphs, platform=paper_platform(), rng=jax.random.PRNGKey(0))
    tr.save_policy(str(tmp_path / "ckpt"), step=1)
    man = policy_manifest(str(tmp_path / "ckpt"))
    assert man["engine"] == "scan"
    assert man["config"]["batch_chains"] == 2
    # and the round-trip still works through the engine validation
    tr2 = MultiGraphTrainer(tr.cfg)
    arrays0 = extract_features(graphs[0], tr.feature_config)
    tr2.init(jax.random.PRNGKey(1), arrays0)
    assert tr2.load_policy(str(tmp_path / "ckpt")) == 1


# ------------------------------------------------------------- reward pipeline
def test_reward_pipeline_window_scoring_matches_backends(diamond):
    rng = np.random.default_rng(2)
    plat = paper_platform()
    T, B = 3, 2
    fines = rng.integers(0, 2, (T, B, diamond.num_nodes))
    # host reward_fn pipeline == reference backend pipeline (same scheduler)
    def reward_fn(p):
        r = simulate(diamond, p, plat)
        return r.reward, r.latency
    r_host, l_host = RewardPipeline.from_reward_fn(
        reward_fn).score_window(fines)
    r_ref, l_ref = RewardPipeline.from_platform(
        diamond, plat, "reference").score_window(fines)
    np.testing.assert_allclose(r_host, r_ref, rtol=1e-12)
    np.testing.assert_allclose(l_host, l_ref, rtol=1e-12)
    # scan pipeline agrees to kernel tolerance
    r_scan, l_scan = RewardPipeline.from_platform(
        diamond, plat, "scan").score_window(fines)
    np.testing.assert_allclose(l_scan, l_ref, rtol=RTOL)
