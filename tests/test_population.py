"""Population search (PBT culling / elite exchange / greedy restarts) and
the async episode prefetcher.

Invariant under test throughout: **culling and exchange never lose the
global best** — per graph row, ``min(best_latency)`` after any sequence of
window updates and PBT transitions equals the running minimum of every
latency ever fed in (the best chain is an elite, elites are never culled,
and culled/exchanged chains inherit the best record).  Plus the no-op pin:
``population=None`` leaves every engine bit-for-bit the population-free
build.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HSDAG, HSDAGConfig, extract_features, paper_platform)
from repro.core.features import batch_graph_arrays, shared_feature_config
from repro.core.costmodel import sim_arrays_batch
from repro.core.sim import (DynamicRolloutEngine, ShardedRolloutEngine,
                            get_backend)
from repro.core.train import population as popmod
from repro.core.train.loop import EpisodePrefetcher, make_chain_rngs
from repro.core.train.population import (ChainState, PopulationConfig,
                                         PopulationController, chain_counts,
                                         init_chain_state, pbt_rows,
                                         update_chain_bests)
from repro.core.train.sampler import CurriculumSampler
from repro.graphs import build_corpus

from conftest import given, settings, st

PLAT = paper_platform()
POP = PopulationConfig(cull_every=2, greedy_restart_every=2)


def _cfg(**kw):
    base = dict(num_devices=2, hidden_channel=16, max_episodes=4,
                update_timestep=2, batch_chains=8)
    base.update(kw)
    return HSDAGConfig(**base)


def _graphs(count=3, size=12, seed=0):
    return list(build_corpus(
        f"synthetic:family=mixed:count={count}:size={size}:seed={seed}"))


# ------------------------------------------------------------------- config
def test_population_config_roundtrip_and_validation():
    pc = PopulationConfig(cull_every=3, exchange_fraction=0.5)
    assert PopulationConfig.from_json(pc.to_json()) == pc
    with pytest.raises(ValueError, match="unknown PopulationConfig fields"):
        PopulationConfig.from_json('{"cull_evry": 3}')
    with pytest.raises(ValueError, match="cull_every"):
        PopulationConfig(cull_every=0)
    with pytest.raises(ValueError, match="cull_fraction"):
        PopulationConfig(cull_fraction=1.0)
    with pytest.raises(ValueError, match="temp_min"):
        PopulationConfig(temp_min=0.9, init_lo=0.7)


def test_chain_counts_disjointness_guard():
    assert chain_counts(PopulationConfig(), 8) == (2, 2)
    assert chain_counts(PopulationConfig(), 4) == (1, 1)
    with pytest.raises(ValueError, match="too small"):
        chain_counts(PopulationConfig(elite_fraction=0.5,
                                      cull_fraction=0.75), 4)


# ---------------------------------------------------------------- pbt math
def test_pbt_rows_decisions():
    cfg = PopulationConfig()
    G, B = 3, 16
    rng = np.random.default_rng(0)
    lat = jnp.asarray(rng.uniform(1.0, 2.0, (G, B)), jnp.float32)
    temp = jnp.ones((G, B), jnp.float32)
    culled, inherit, new_temp, jstar = pbt_rows(
        cfg, jax.random.PRNGKey(3), lat, temp, jnp.arange(G))
    culled, inherit = np.asarray(culled), np.asarray(inherit)
    n_elite, n_cull = chain_counts(cfg, B)
    lat_np = np.asarray(lat)
    for g in range(G):
        assert culled[g].sum() == n_cull
        # the culled chains are exactly the worst n_cull by best latency
        worst = set(np.argsort(lat_np[g])[-n_cull:])
        assert set(np.flatnonzero(culled[g])) == worst
        # elites (incl. the best chain) are never culled nor exchanged
        elites = np.argsort(lat_np[g])[:n_elite]
        assert not culled[g][elites].any()
        assert not inherit[g][elites].any()
        assert int(jstar[g]) == int(np.argmin(lat_np[g]))
        # every culled chain also inherits the best record
        assert inherit[g][culled[g]].all()
    assert (np.asarray(new_temp) >= cfg.temp_min).all()
    assert (np.asarray(new_temp) <= cfg.temp_max).all()
    # survivors keep their temperature
    assert np.array_equal(np.asarray(new_temp)[~culled],
                          np.asarray(temp)[~culled])


def _apply_pbt_records(cfg, pop, G, B):
    """The engines' record-rewrite step (temperature + best inheritance)."""
    k_use, _, k_next = jax.random.split(pop.rng, 3)
    culled, inherit, new_temp, jstar = pbt_rows(
        cfg, k_use, pop.best_latency, pop.temperature, jnp.arange(G))
    onehot = jnp.arange(B)[None, :] == jstar[:, None]
    lat_star = jnp.sum(jnp.where(onehot, pop.best_latency, 0.0), axis=1)
    fine_star = jnp.sum(pop.best_fine * onehot[:, :, None], axis=1)
    return pop._replace(
        temperature=new_temp,
        best_latency=jnp.where(inherit, lat_star[:, None],
                               pop.best_latency),
        best_fine=jnp.where(inherit[:, :, None], fine_star[:, None],
                            pop.best_fine),
        rng=k_next)


def _check_monotone_schedule(seed: int, G: int, B: int, windows: int,
                             cull_after) -> None:
    """Feed random latencies, interleave PBT per ``cull_after`` — the
    per-row best must always equal the running min of everything fed."""
    cfg = PopulationConfig()
    rng = np.random.default_rng(seed)
    pop = init_chain_state(cfg, jax.random.PRNGKey(seed), num_graphs=G,
                           num_chains=B, num_nodes=4)
    running = np.full(G, np.inf)
    for w in range(windows):
        lat = rng.uniform(0.5, 2.0, (2, G, B))
        fines = rng.integers(0, 2, (2, G, B, 4))
        pop = update_chain_bests(pop, jnp.asarray(fines),
                                 jnp.asarray(lat, jnp.float32))
        running = np.minimum(running, lat.min(axis=(0, 2)).astype(np.float32))
        if cull_after(w):
            pop = _apply_pbt_records(cfg, pop, G, B)
        np.testing.assert_allclose(
            np.asarray(pop.best_latency).min(axis=1), running, rtol=1e-6)


def test_best_never_lost_under_cull_schedules():
    _check_monotone_schedule(0, 2, 8, 6, lambda w: w % 2 == 1)
    _check_monotone_schedule(1, 3, 12, 5, lambda w: True)   # cull every window
    _check_monotone_schedule(2, 1, 4, 8, lambda w: w in (0, 3, 4))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 3), st.integers(4, 16),
       st.lists(st.booleans(), min_size=1, max_size=8))
def test_best_never_lost_property(seed, G, B, schedule):
    """Hypothesis: arbitrary cull schedules never lose the global best."""
    _check_monotone_schedule(seed, G, B, len(schedule),
                             lambda w: schedule[w])


# ------------------------------------------------------------ engine paths
@pytest.fixture(scope="module")
def pop_fixture():
    from repro.core.train.curriculum import _operands
    graphs = _graphs(count=3, size=12)
    cfg = _cfg()
    agent = HSDAG(cfg)
    fc = shared_feature_config(graphs)
    arrays = [extract_features(g, fc) for g in graphs]
    agent.init(jax.random.PRNGKey(0), arrays[0])
    v_max = max(g.num_nodes for g in graphs)
    e_max = max(1, max(a.edges.shape[0] for a in arrays))
    ga = batch_graph_arrays(arrays, v_max=v_max, e_max=e_max)
    sb = sim_arrays_batch(graphs, PLAT, v_max=v_max)
    ops = _operands(ga, jax.tree.map(jnp.asarray, sb.arrays))
    return agent, cfg, ops, v_max


def test_population_none_is_structural_noop(pop_fixture):
    """An engine built WITH population= runs its base path bit-for-bit
    like an engine built without (the pop path is strictly additive)."""
    agent, cfg, ops, v_max = pop_fixture
    backend = get_backend("scan")
    base = DynamicRolloutEngine(agent._step, cfg, backend=backend)
    pop_eng = DynamicRolloutEngine(agent._step, cfg, backend=backend,
                                   population=POP)
    G, B = 3, cfg.batch_chains
    z = jnp.broadcast_to(ops.x0[:, None], (G, B) + ops.x0.shape[1:])
    rngs = make_chain_rngs(jax.random.PRNGKey(1), G, B)
    o1 = base.rollout_window(ops, agent.params, z, rngs, num_steps=2,
                             start_first=True)
    o2 = pop_eng.rollout_window(ops, agent.params, z, rngs, num_steps=2,
                                start_first=True)
    for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and an engine without population= refuses the pop API loudly
    with pytest.raises(ValueError, match="population"):
        base.rollout_window_pop(ops, agent.params, z, rngs, None,
                                num_steps=2, start_first=True)


def test_temperature_one_matches_base_bitwise(pop_fixture):
    """T=1 tempering is the identity: the pop rollout at all-ones
    temperature reproduces the base rollout bit for bit."""
    agent, cfg, ops, v_max = pop_fixture
    eng = DynamicRolloutEngine(agent._step, cfg, backend=get_backend("scan"),
                               population=POP)
    G, B = 3, cfg.batch_chains
    pop = eng.init_population(jax.random.PRNGKey(7), num_graphs=G,
                              num_chains=B, num_nodes=v_max,
                              temperatures=jnp.ones((G, B), jnp.float32))
    z = jnp.broadcast_to(ops.x0[:, None], (G, B) + ops.x0.shape[1:])
    rngs = make_chain_rngs(jax.random.PRNGKey(1), G, B)
    out_pop = eng.rollout_window_pop(ops, agent.params, z, rngs, pop,
                                     num_steps=2, start_first=True)
    out_base = eng.rollout_window(ops, agent.params, z, rngs, num_steps=2,
                                  start_first=True)
    np.testing.assert_array_equal(np.asarray(out_pop[4]),      # fines
                                  np.asarray(out_base[3]))
    np.testing.assert_array_equal(np.asarray(out_pop[7]),      # latencies
                                  np.asarray(out_base[6]))


def test_engine_pbt_monotone_and_greedy_restart(pop_fixture):
    """In-jit pbt_step over live rollouts keeps the best-record monotone,
    in both restart-from-best and restart-from-greedy modes."""
    agent, cfg, ops, v_max = pop_fixture
    eng = DynamicRolloutEngine(agent._step, cfg, backend=get_backend("scan"),
                               population=POP)
    G, B = 3, cfg.batch_chains
    pop = eng.init_population(jax.random.PRNGKey(7), num_graphs=G,
                              num_chains=B, num_nodes=v_max)
    z = jnp.broadcast_to(ops.x0[:, None], (G, B) + ops.x0.shape[1:])
    rngs = make_chain_rngs(jax.random.PRNGKey(1), G, B)
    best_seen = np.full(G, np.inf)
    for w in range(4):
        z, rngs, pop, _, _, _, _, lat = eng.rollout_window_pop(
            ops, agent.params, z, rngs, pop, num_steps=2,
            start_first=(w == 0))
        best_seen = np.minimum(best_seen, np.asarray(lat).min(axis=(0, 2)))
        pop, z = eng.pbt_step(ops, agent.params, pop, z,
                              use_greedy=(w % 2 == 1))
        np.testing.assert_allclose(np.asarray(pop.best_latency).min(axis=1),
                                   best_seen, rtol=1e-6)


def test_sharded_pop_matches_dynamic_at_1x1(pop_fixture):
    """mesh=(1,1) population path is bitwise the dynamic engine's."""
    agent, cfg, ops, v_max = pop_fixture
    backend = get_backend("scan")
    dyn = DynamicRolloutEngine(agent._step, cfg, backend=backend,
                               population=POP)
    shd = ShardedRolloutEngine(agent._step, cfg, backend=backend,
                               mesh_shape=(1, 1), population=POP)
    G, B = 3, cfg.batch_chains
    pop = dyn.init_population(jax.random.PRNGKey(7), num_graphs=G,
                              num_chains=B, num_nodes=v_max)
    z = jnp.broadcast_to(ops.x0[:, None], (G, B) + ops.x0.shape[1:])
    rngs = make_chain_rngs(jax.random.PRNGKey(1), G, B)
    o_d = dyn.rollout_window_pop(ops, agent.params, z, rngs, pop,
                                 num_steps=2, start_first=True)
    o_s = shd.rollout_window_pop(ops, agent.params, z, rngs, pop,
                                 num_steps=2, start_first=True)
    for a, b in zip(jax.tree.leaves(o_d), jax.tree.leaves(o_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    w = jnp.asarray(np.random.default_rng(0).standard_normal((2, G, B)),
                    jnp.float32)
    g_d = dyn.window_grads_pop(ops, agent.params, z, o_d[3], w,
                               pop.temperature, num_steps=2,
                               start_first=True)
    g_s = shd.window_grads_pop(ops, agent.params, z, o_d[3], w,
                               pop.temperature, num_steps=2,
                               start_first=True)
    for a, b in zip(jax.tree.leaves(g_d), jax.tree.leaves(g_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ug in (False, True):
        p_d, z_d = dyn.pbt_step(ops, agent.params, o_d[2], o_d[0],
                                use_greedy=ug)
        p_s, z_s = shd.pbt_step(ops, agent.params, o_d[2], o_d[0],
                                use_greedy=ug)
        for a, b in zip(jax.tree.leaves((p_d, z_d)),
                        jax.tree.leaves((p_s, z_s))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------- search/train_multi
def test_search_population_culls_and_tracks_best():
    graphs = _graphs(count=1)
    agent = HSDAG(_cfg(max_episodes=5))
    arrays = extract_features(graphs[0])
    res = agent.search(graphs[0], arrays, platform=PLAT,
                       rng=jax.random.PRNGKey(0), population=POP)
    assert any(h["culled"] for h in res.history)
    assert np.isfinite(res.best_latency)
    bests = [h["best_latency"] for h in res.history]
    assert bests == sorted(bests, reverse=True)      # monotone nonincreasing
    pop_bests = [h["pop_best_latency"] for h in res.history]
    assert pop_bests == sorted(pop_bests, reverse=True)
    # the in-jit record and the host tracker agree on the global best
    assert res.best_latency <= pop_bests[-1] + 1e-9


def test_train_multi_population_tracker_survives_resets():
    graphs = _graphs(count=3)
    from repro.core import MultiGraphTrainer
    tr = MultiGraphTrainer(_cfg(max_episodes=4))
    res = tr.train(graphs, platform=PLAT, rng=jax.random.PRNGKey(0),
                   population=POP)
    assert any(h.get("culled") for h in res.history)
    assert all(np.isfinite(l) for l in res.best_latencies)
    for h0, h1 in zip(res.history, res.history[1:]):
        assert all(b1 <= b0 + 1e-12 for b0, b1 in
                   zip(h0["per_graph_best"], h1["per_graph_best"]))


def test_scalar_engine_rejects_population():
    graphs = _graphs(count=1)
    agent = HSDAG(_cfg(batch_chains=1, engine="scalar"))
    arrays = extract_features(graphs[0])
    with pytest.raises(ValueError, match="population search needs"):
        agent.search(graphs[0], arrays, platform=PLAT, population=POP)


# -------------------------------------------------------------- controller
def test_controller_state_roundtrip_continues_identically():
    import json

    def drive(ctl, episodes, rng):
        out = []
        for _ in range(episodes):
            lat = rng.uniform(0.5, 2.0, (2, 3, 8))
            out.append((ctl.observe_episode(lat), ctl.temps.copy()))
        return out

    a = PopulationController(PopulationConfig(cull_every=2), num_chains=8,
                             in_jit_pbt=False)
    drive(a, 3, np.random.default_rng(0))
    state = json.loads(json.dumps(a.state_dict()))
    b = PopulationController(PopulationConfig(cull_every=2), num_chains=8,
                             in_jit_pbt=False)
    b.load_state_dict(state)
    r1, r2 = np.random.default_rng(1), np.random.default_rng(1)
    for (c1, t1), (c2, t2) in zip(drive(a, 4, r1), drive(b, 4, r2)):
        assert c1 == c2
        np.testing.assert_array_equal(t1, t2)


# ----------------------------------------------------------- prefetcher
def test_prefetcher_hit_miss_and_identity():
    calls = []

    def build(a, b):
        calls.append((a, b))
        return {"key": (a, b), "payload": a * 10 + b}

    pf = EpisodePrefetcher(build)
    try:
        pf.schedule((1, 2))
        payload, wait = pf.get((1, 2))
        assert payload == build(1, 2) and pf.hits == 1 and wait >= 0.0
        # mispredicted key → miss, synchronous fallback, still correct
        pf.schedule((3, 4))
        payload, _ = pf.get((9, 9))
        assert payload["key"] == (9, 9) and pf.misses == 1
    finally:
        pf.close()


def test_prefetcher_propagates_worker_errors():
    def boom(_):
        raise RuntimeError("featurization failed")

    pf = EpisodePrefetcher(boom)
    try:
        pf.schedule((0,))
        with pytest.raises(RuntimeError, match="featurization failed"):
            pf.get((0,))
    finally:
        pf.close()


def test_prefetcher_close_is_idempotent_and_leak_free():
    before = {t.name for t in threading.enumerate()}
    pf = EpisodePrefetcher(lambda x: x, name="leak-probe")
    assert pf.alive
    pf.schedule((1,))
    pf.close()
    pf.close()                                   # idempotent
    assert not pf.alive
    after = {t.name for t in threading.enumerate()}
    assert "leak-probe" not in after
    assert after <= before


def test_sampler_peek_is_exact_for_rng_only_strategies():
    for strategy in ("uniform", "stratified"):
        s = CurriculumSampler([[0, 1, 2], [3, 4]], graphs_per_episode=2,
                              strategy=strategy, seed=3)
        for _ in range(6):
            predicted = s.peek()
            assert predicted == s.sample()


# ------------------------------------------------------------ corpus trainer
def test_corpus_prefetch_is_bitwise_neutral():
    from repro.core.train import CurriculumTrainer
    graphs = _graphs(count=6)
    results = {}
    for prefetch in ("off", "on"):
        tr = CurriculumTrainer(_cfg(), max_buckets=2, graphs_per_episode=2,
                               prefetch=prefetch)
        res = tr.train_corpus(graphs, platform=PLAT,
                              rng=jax.random.PRNGKey(0))
        results[prefetch] = (res, tr.params)
        assert all("batch_wait_s" in h for h in res.history)
    r_off, p_off = results["off"]
    r_on, p_on = results["on"]
    np.testing.assert_array_equal(r_off.best_latencies, r_on.best_latencies)
    assert [h["mean_reward"] for h in r_off.history] == \
        [h["mean_reward"] for h in r_on.history]
    for a, b in zip(jax.tree.leaves(p_off), jax.tree.leaves(p_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the worker is gone once training returns
    assert not any(t.name == "episode-prefetch"
                   for t in threading.enumerate())


def test_corpus_population_culls_episodically():
    from repro.core.train import CurriculumTrainer
    graphs = _graphs(count=6)
    tr = CurriculumTrainer(_cfg(), max_buckets=2, graphs_per_episode=2,
                           population=PopulationConfig(cull_every=2))
    res = tr.train_corpus(graphs, platform=PLAT, rng=jax.random.PRNGKey(0))
    assert any(h.get("culled") for h in res.history)
    assert all("pop_best_latency" in h for h in res.history)


def test_corpus_population_resume_guard(tmp_path):
    from repro.core.train import CurriculumTrainer
    graphs = _graphs(count=4)
    ck = str(tmp_path / "run")
    tr = CurriculumTrainer(_cfg(max_episodes=2), max_buckets=2,
                           graphs_per_episode=2,
                           population=PopulationConfig(cull_every=2))
    tr.train_corpus(graphs, platform=PLAT, rng=jax.random.PRNGKey(0),
                    checkpoint_dir=ck, checkpoint_every=1)
    bare = CurriculumTrainer(_cfg(max_episodes=3), max_buckets=2,
                             graphs_per_episode=2)
    with pytest.raises(ValueError, match="population"):
        bare.train_corpus(graphs, platform=PLAT, rng=jax.random.PRNGKey(0),
                          checkpoint_dir=ck, resume=True)
    again = CurriculumTrainer(_cfg(max_episodes=3), max_buckets=2,
                              graphs_per_episode=2,
                              population=PopulationConfig(cull_every=2))
    res = again.train_corpus(graphs, platform=PLAT,
                             rng=jax.random.PRNGKey(0),
                             checkpoint_dir=ck, resume=True)
    assert len(res.history) >= 1                 # continued past episode 2
