"""Checkpoint manager: atomicity, keep-k, async, resume, elastic re-shard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": jnp.float32(7.5)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t, meta={"loss": 1.25})
    assert mgr.latest_step() == 3
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    r = mgr.restore(3, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.manifest(3)["loss"] == 1.25


def test_keep_k_prunes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    for s in range(3):
        mgr.save(s, t)
    mgr.wait()
    assert mgr.latest_step() == 2
    mgr.close()


def test_tmp_dirs_ignored_and_gced(tmp_path):
    # A crashed save leaves a .tmp dir: it must be invisible and cleaned.
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    crash = os.path.join(str(tmp_path), "step_0000000002.tmp")
    os.makedirs(crash)
    assert mgr.latest_step() == 1
    mgr2 = CheckpointManager(str(tmp_path))
    assert not os.path.exists(crash)
    assert mgr2.latest_step() == 1


def test_restore_missing_leaf_errors(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        mgr.restore(0, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_restore_preserves_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    mgr.save(0, t)
    r = mgr.restore(0, t)
    assert r["w"].dtype == jnp.bfloat16


def test_elastic_reshard_restore(tmp_path):
    """Save unsharded, restore with an explicit (single-device) sharding —
    the re-shard path used when the restoring job has a different mesh."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(0, t)
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    sh = {"w": NamedSharding(mesh, PartitionSpec(None, None))}
    r = mgr.restore(0, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
