"""Cross-cutting hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # this module is entirely property-based
from hypothesis import given, settings, strategies as st

from repro.core import extract_features, FeatureConfig, paper_platform, simulate
from repro.core.costmodel import (op_class, sim_arrays, sim_arrays_batch,
                                  simulate_jax, simulate_multi,
                                  tpu_stage_platform)
from repro.core.gpn import gpn_init, gpn_apply
from repro.core.gnn import encoder_apply, encoder_init
from repro.optim import adamw, apply_updates, clip_by_global_norm

from conftest import random_dag


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 28), st.integers(0, 500), st.integers(0, 3))
def test_placement_to_fine_consistency(n, seed, param_seed):
    """fine placement == coarse placement gathered via labels (X mapping)."""
    from repro.core.policy import policy_apply, policy_init
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    arr = extract_features(g, FeatureConfig(d_pos=8))
    k = jax.random.PRNGKey(param_seed)
    enc = encoder_init(k, arr.x.shape[1], 16)
    gpn = gpn_init(jax.random.fold_in(k, 1), 16)
    pol = policy_init(jax.random.fold_in(k, 2), 16, 3)
    z = encoder_apply(enc, jnp.asarray(arr.x), jnp.asarray(arr.adj))
    parse = gpn_apply(gpn, z, jnp.asarray(arr.edges), jnp.asarray(arr.adj))
    out = policy_apply(pol, parse.pooled_z, parse.active, parse.labels,
                       jax.random.fold_in(k, 3))
    fine = np.asarray(out.fine_placement)
    coarse = np.asarray(out.coarse_placement)
    labels = np.asarray(parse.labels)
    np.testing.assert_array_equal(fine, coarse[labels])
    # all nodes in a group share a device (the grouper-placer contract)
    for c in np.unique(labels):
        assert len(set(fine[labels == c])) == 1


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 24), st.integers(0, 500))
def test_simulator_placement_permutation_invariance(n, seed):
    """Swapping the two identical queues of a device never changes latency;
    relabeling devices of a symmetric platform permutes busy times."""
    from repro.core.costmodel import DeviceSpec, Platform, _uniform_links
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    dev = DeviceSpec("d", "gpu", 1e12, 1e11, 1e-6)
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    plat = Platform((dev, dev), bw, lat)
    p = rng.integers(0, 2, n)
    r1 = simulate(g, p, plat)
    r2 = simulate(g, 1 - p, plat)
    assert np.isclose(r1.latency, r2.latency)
    np.testing.assert_allclose(r1.per_device_busy,
                               r2.per_device_busy[::-1])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 100))
def test_adamw_descends_quadratic(dim, seed):
    rng = jax.random.PRNGKey(seed)
    target = jax.random.normal(rng, (dim,))
    params = {"w": jnp.zeros((dim,))}
    opt = adamw(0.1)
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(loss(params)) < l0 * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.floats(0.1, 10.0), st.integers(0, 100))
def test_clip_by_global_norm_bound(nleaves, max_norm, seed):
    rng = np.random.default_rng(seed)
    tree = {f"p{i}": jnp.asarray(rng.standard_normal(7).astype(np.float32))
            for i in range(nleaves)}
    clipped, norm = clip_by_global_norm(tree, max_norm)
    from repro.optim import global_norm
    assert float(global_norm(clipped)) <= max_norm * (1 + 1e-5)
    if float(norm) <= max_norm:   # no-op when under the bound
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(clipped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(3, 16), min_size=1, max_size=4),
       st.integers(0, 30), st.integers(0, 500), st.booleans())
def test_simulate_multi_matches_reference(sizes, extra_pad, seed, use_tpu):
    """Padded multi-graph batches never corrupt rewards: for random DAG
    batches on both platforms and any padding amount (including V_max ≫ V),
    ``simulate_multi`` matches per-graph ``simulate_jax`` bitwise and the
    Python ``simulate`` reference within 1e-5 relative latency."""
    rng = np.random.default_rng(seed)
    graphs = [random_dag(rng, n, p=0.25) for n in sizes]
    plat = tpu_stage_platform(2) if use_tpu else paper_platform()
    ndev = plat.num_devices
    v_max = max(sizes) + extra_pad
    batch = sim_arrays_batch(graphs, plat, v_max=v_max)
    placements = np.zeros((len(graphs), v_max), dtype=np.int64)
    for i, g in enumerate(graphs):
        placements[i, :g.num_nodes] = rng.integers(0, ndev, g.num_nodes)
    res = simulate_multi(batch, placements)
    for i, g in enumerate(graphs):
        p = placements[i, :g.num_nodes]
        jx = simulate_jax(sim_arrays(g, plat), p.astype(np.int32))
        assert float(jx.latency) == float(res.latency[i])
        ref = simulate(g, p, plat)
        np.testing.assert_allclose(res.latency[i], ref.latency, rtol=1e-5)
        np.testing.assert_allclose(res.reward[i], ref.reward, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 20), st.integers(0, 300))
def test_colocated_placement_latency_close_to_expanded(n, seed):
    """Placing the co-located graph and expanding to the fine graph gives a
    latency within dispatch-overhead slack of the coarse estimate (the
    Appendix-G coarsening is cost-faithful)."""
    from repro.core import colocate_chains
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n, p=0.12)
    coarse, labels = colocate_chains(g)
    plat = paper_platform()
    cp = rng.integers(0, 2, coarse.num_nodes)
    uniq = {lab: i for i, lab in enumerate(sorted(set(labels.tolist())))}
    fine_placement = np.array([cp[uniq[lab]] for lab in labels])
    lat_fine = simulate(g, fine_placement, plat).latency
    lat_coarse = simulate(coarse, cp, plat).latency
    # same flops, same transfers across boundaries; fine pays more dispatch
    assert lat_fine >= lat_coarse * 0.5
    assert lat_fine <= lat_coarse * 3 + n * 40e-6


# ------------------------------------------------- non-uniform link matrices

def _random_nonuniform_platform(rng, d, *, queues=1):
    """Random tiered-looking fleet: every ordered pair gets its own link."""
    from repro.core.costmodel import DeviceSpec, Platform
    bw = rng.uniform(5e8, 5e10, (d, d))
    bw[np.eye(d, dtype=bool)] = np.inf
    lat = rng.uniform(0.0, 2e-5, (d, d))
    np.fill_diagonal(lat, 0.0)
    dev = DeviceSpec("d", "gpu", 1e12, 1e11, 1e-6, parallel_queues=queues)
    return Platform((dev,) * d, bw, lat)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 20), st.integers(2, 4), st.integers(0, 500))
def test_simulator_device_relabeling_equivariance_nonuniform(n, d, seed):
    """Relabeling devices (permuting both link matrices and the placement)
    never changes the makespan and permutes busy times — even when every
    ordered pair has its own bandwidth/latency."""
    from repro.core.costmodel import Platform
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    plat = _random_nonuniform_platform(rng, d)
    p = rng.integers(0, d, n)
    perm = rng.permutation(d)            # new index -> old index
    inv = np.empty(d, int)
    inv[perm] = np.arange(d)
    plat2 = Platform(tuple(plat.devices[k] for k in perm),
                     plat.link_bw[np.ix_(perm, perm)],
                     plat.link_latency[np.ix_(perm, perm)])
    r1 = simulate(g, p, plat)
    r2 = simulate(g, inv[p], plat2)
    assert np.isclose(r1.latency, r2.latency)
    np.testing.assert_allclose(r2.per_device_busy, r1.per_device_busy[perm])


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 20), st.integers(2, 4), st.integers(0, 500))
def test_simulate_jax_matches_reference_nonuniform_links(n, d, seed):
    """The fused JAX simulator agrees with the Python reference on random
    non-uniform link matrices (the topology-builder regime)."""
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    plat = _random_nonuniform_platform(rng, d, queues=2)
    p = rng.integers(0, d, n)
    ref = simulate(g, p, plat)
    jx = simulate_jax(sim_arrays(g, plat), p.astype(np.int32))
    np.testing.assert_allclose(float(jx.latency), ref.latency, rtol=1e-5)
    np.testing.assert_allclose(float(jx.reward), ref.reward, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 18), st.integers(2, 4), st.integers(0, 500))
def test_makespan_monotone_in_links_contention_free(n, d, seed):
    """Uniformly better links (elementwise bw up, latency down) never hurt
    the makespan when ample queues keep the schedule contention-free.  (The
    greedy list scheduler is NOT monotone under queue contention — Graham's
    anomalies — so ample queues are part of the property.)"""
    from repro.core.costmodel import Platform
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    plat = _random_nonuniform_platform(rng, d, queues=32)
    bw2 = plat.link_bw * rng.uniform(1.0, 4.0, (d, d))
    bw2[np.eye(d, dtype=bool)] = np.inf
    lat2 = plat.link_latency * rng.uniform(0.0, 1.0, (d, d))
    np.fill_diagonal(lat2, 0.0)
    plat2 = Platform(plat.devices, bw2, lat2)
    p = rng.integers(0, d, n)
    assert simulate(g, p, plat2).latency <= \
        simulate(g, p, plat).latency + 1e-12


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 20), st.integers(2, 4), st.integers(0, 500))
def test_makespan_at_least_critical_path_nonuniform(n, d, seed):
    """The free-transfer best-device critical path stays a lower bound on
    the makespan for arbitrary non-uniform link matrices."""
    from repro.core import critical_path
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    plat = _random_nonuniform_platform(rng, d, queues=2)
    p = rng.integers(0, d, n)
    assert simulate(g, p, plat).latency >= critical_path(g, plat) - 1e-12
