"""Tests for the latency simulator (DESIGN.md §3 reward backend)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: property tests skip cleanly
    from conftest import given, settings, st

from repro.core import (critical_path, paper_platform, simulate,
                        tpu_stage_platform)
from repro.core.costmodel import (DeviceSpec, Platform, _uniform_links,
                                  op_class)

from conftest import make_diamond, random_dag


def test_single_device_latency_is_sum_of_op_times(diamond):
    plat = paper_platform()
    res = simulate(diamond, np.zeros(diamond.num_nodes, int), plat)
    # On one device with one queue it would be the serial sum; with multiple
    # queues it can only be faster.
    assert res.latency <= res.per_device_busy[0] + 1e-12 or \
        np.isclose(res.latency, res.per_device_busy[0])
    assert res.transfer_time == 0.0
    assert not res.oom


def test_makespan_lower_bounded_by_critical_path(diamond):
    plat = paper_platform()
    cp = critical_path(diamond, plat)
    for placement in ([0] * 7, [1] * 7, [0, 1, 0, 1, 0, 1, 0]):
        res = simulate(diamond, np.array(placement), plat)
        assert res.latency >= cp - 1e-12


def test_cross_device_edges_pay_transfer(diamond):
    plat = paper_platform()
    mixed = np.array([0, 0, 1, 0, 1, 0, 0])
    res = simulate(diamond, mixed, plat)
    assert res.transfer_time > 0


def test_transfer_monotonicity(diamond):
    """Slower links can never reduce the makespan (property of the model)."""
    fast = paper_platform()
    bw, lat = _uniform_links(2, bw=1e9, lat=1e-3)
    slow = Platform(fast.devices, bw, lat)
    mixed = np.array([0, 1, 0, 1, 0, 1, 0])
    assert simulate(diamond, mixed, slow).latency >= \
        simulate(diamond, mixed, fast).latency


def test_reward_is_inverse_latency(diamond):
    plat = paper_platform()
    res = simulate(diamond, np.zeros(7, int), plat)
    assert np.isclose(res.reward, 1.0 / res.latency)


def test_oom_gives_zero_reward(diamond):
    dev = DeviceSpec("tiny", "gpu", 1e12, 1e11, 1e-6, mem_capacity=10.0)
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    plat = Platform((dev, dev), bw, lat)
    res = simulate(diamond, np.zeros(7, int), plat)
    assert res.oom and res.reward == 0.0


def test_data_ops_are_free():
    from repro.core import CompGraph
    g = CompGraph("c")
    g.add_op("w", "Const", output_shape=(1024,), bytes_out=4096)
    g.add_op("m", "MatMul", ["w"], (1, 4), flops=1e6, bytes_out=16)
    plat = paper_platform()
    # Placing the const on the other device must not add transfer time.
    r1 = simulate(g, np.array([0, 1]), plat)
    r2 = simulate(g, np.array([1, 1]), plat)
    assert np.isclose(r1.latency, r2.latency)
    assert r1.transfer_time == 0.0


def test_parallel_queues_speed_up_branches(diamond):
    base = paper_platform()
    one_q = DeviceSpec("CPU", "cpu", 1.1e12, 76e9, 1.5e-6, 64e9,
                       base.devices[0].efficiency, parallel_queues=1)
    plat1 = Platform((one_q, base.devices[1]), base.link_bw, base.link_latency)
    p = np.zeros(7, int)
    assert simulate(diamond, p, base).latency <= \
        simulate(diamond, p, plat1).latency + 1e-15


def test_tpu_stage_platform_shapes():
    plat = tpu_stage_platform(num_stages=4)
    assert plat.num_devices == 4
    assert plat.devices[0].peak_flops == 197e12 * 256


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 25), st.integers(0, 10_000))
def test_makespan_at_least_busiest_device(n, seed):
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    plat = paper_platform()
    placement = rng.integers(0, 2, n)
    res = simulate(g, placement, plat)
    for d in range(2):
        q = plat.devices[d].parallel_queues
        assert res.latency >= res.per_device_busy[d] / q - 1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 25), st.integers(0, 10_000))
def test_makespan_at_least_critical_path_random(n, seed):
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    plat = paper_platform()
    placement = rng.integers(0, 2, n)
    assert simulate(g, placement, plat).latency >= \
        critical_path(g, plat) - 1e-12


# ------------------------------------------- Platform construction validation

def _two_devs():
    dev = DeviceSpec("d", "gpu", 1e12, 1e11, 1e-6)
    return (dev, dev)


def test_platform_rejects_wrong_link_shape():
    bw, lat = _uniform_links(3, 1e9, 1e-6)
    with pytest.raises(ValueError, match=r"link_bw must be \(2, 2\)"):
        Platform(_two_devs(), bw, lat)


def test_platform_rejects_finite_bw_diagonal():
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    bw[1, 1] = 5e9
    with pytest.raises(ValueError, match=r"link_bw\[1, 1\]"):
        Platform(_two_devs(), bw, lat)


def test_platform_rejects_nonzero_latency_diagonal():
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    lat[0, 0] = 1e-9
    with pytest.raises(ValueError, match=r"link_latency\[0, 0\]"):
        Platform(_two_devs(), bw, lat)


def test_platform_names_offending_offdiagonal_entry():
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    bw[0, 1] = 0.0                      # zero bandwidth: divide-by-zero trap
    with pytest.raises(ValueError, match=r"link_bw\[0, 1\].*positive"):
        Platform(_two_devs(), bw, lat)
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    lat[1, 0] = -2e-6
    with pytest.raises(ValueError, match=r"link_latency\[1, 0\]"):
        Platform(_two_devs(), bw, lat)
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    bw[1, 0] = np.inf
    with pytest.raises(ValueError, match=r"link_bw\[1, 0\].*finite"):
        Platform(_two_devs(), bw, lat)


def test_platform_rejects_bad_coords_shape():
    bw, lat = _uniform_links(2, 1e9, 1e-6)
    with pytest.raises(ValueError, match=r"coords must be \(2, C\)"):
        Platform(_two_devs(), bw, lat, coords=np.zeros((3, 2)))
